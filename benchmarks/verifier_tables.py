"""Tables 2 & 3 — systematic comparison of verification algorithms under
matched i.i.d. root-rollout drafts (L1 = 0), plus the delayed-expansion rows
(the "X, delayed expansion" rows of Tables 8-15).

For each (family x domain x sampling) cell, every verifier picks its best
configuration from the (K, L) grid by the requested metric, exactly as the
paper does ("we select the branching factor K in [1,4] and block length L in
[0,8] that maximizes the block-efficiency or throughput").

Estimation:  OT-based methods use the exact Eq. 3 conditional estimator over
s sampled trees; Traversal/BV/Naive use their exact conditional block-length
laws over the same tree samples.  Verification variance is therefore zero;
only drafting variance remains.

``--matrix`` instead runs the Table-1-style cross-verifier matrix over the
WHOLE core/verify.py registry — losslessness gap x block efficiency x
engine-level batched==sequential exactness, for every registered verifier,
both target-pass strategies (tree and replay archs from the configs/ zoo)
and the paper's sampling grid — and emits the machine-readable
``BENCH_verifier_matrix.json`` document (benchmarks/common.py
``write_bench_json``) that scripts/verifier_matrix.sh gates CI on.  Quick
mode (the default) is the per-PR gate; ``--full`` is the weekly matrix.
"""
from __future__ import annotations

import argparse

import numpy as np

try:
    from benchmarks.common import (
        DOMAINS,
        FAMILIES,
        SAMPLING,
        SAMPLING_QUICK,
        family_latency,
        make_process,
        write_bench_json,
    )
except ImportError:  # executed as a script: benchmarks/ itself is sys.path[0]
    from common import (
        DOMAINS,
        FAMILIES,
        SAMPLING,
        SAMPLING_QUICK,
        family_latency,
        make_process,
        write_bench_json,
    )
from repro.core.delayed import expected_block_efficiency, expected_block_efficiency_traversal
from repro.core.enumerate import mean_block_len
from repro.core.trees import attach_target, build_delayed_tree
from repro.core.verify import verify_topdown_output_dist

OT_METHODS = ["nss", "naivetree", "spectr", "specinfer", "khisti"]
SINGLE_PATH = ["naive", "bv"]  # K = 1 only
ALL_METHODS = OT_METHODS + SINGLE_PATH + ["traversal"]


def block_efficiency(proc, method: str, K: int, L1: int, L2: int, s: int, seed: int) -> float:
    rng = np.random.default_rng(seed)
    vals = []
    for _ in range(s):
        tree = build_delayed_tree(rng, proc.q, K, L1, L2)
        attach_target(tree, proc.p)
        if method == "traversal" or method == "bv":
            vals.append(expected_block_efficiency_traversal(tree))
        elif method == "naive":
            d = verify_topdown_output_dist(tree, "naive")
            vals.append(mean_block_len(d))
        else:
            vals.append(expected_block_efficiency(tree, method))
    return float(np.mean(vals))


def grid_for(method: str, quick: bool, delayed: bool):
    Ks = [1] if method in SINGLE_PATH else ([2, 4] if quick else [1, 2, 3, 4])
    Ls = [2, 4, 6] if quick else [1, 2, 3, 4, 6, 8]
    out = []
    for K in Ks:
        for L in Ls:
            if delayed and K > 1:
                # split the same node budget into trunk + branches
                for L1 in ([1, 2] if quick else [1, 2, 3]):
                    if L - L1 >= 1:
                        out.append((K, L1, L - L1))
            else:
                out.append((K, 0, L))
    return out


def run(quick: bool = True, delayed: bool = False, metric: str = "block_efficiency",
        s: int = 4, seed: int = 0):
    """Returns {family: {method: avg}}, detail rows."""
    sampling = SAMPLING_QUICK if quick else SAMPLING
    domains = DOMAINS[:3] if quick else DOMAINS
    rows = []
    agg: dict = {f: {m: [] for m in ALL_METHODS} for f in FAMILIES}
    for family in FAMILIES:
        lat = family_latency(family)
        for domain in domains:
            for (temp, top_p) in sampling:
                proc = make_process(family, domain, temp, top_p)
                for method in ALL_METHODS:
                    best = -1.0
                    best_a = None
                    for (K, L1, L2) in grid_for(method, quick, delayed):
                        be = block_efficiency(proc, method, K, L1, L2, s, seed)
                        score = be if metric == "block_efficiency" else be / lat.action_time(256, K, L1, L2)
                        if score > best:
                            best, best_a = score, (K, L1, L2)
                    agg[family][method].append(best)
                    rows.append(dict(family=family, domain=domain, temp=temp, top_p=top_p,
                                     method=method, score=best, action=best_a))
    table = {f: {m: float(np.mean(v)) for m, v in d.items()} for f, d in agg.items()}
    return table, rows


def print_table(table: dict, title: str):
    methods = sorted(next(iter(table.values())), key=lambda m: np.mean([table[f][m] for f in table]))
    print(f"\n== {title} ==")
    fams = list(table)
    print(f"{'method':12s} " + " ".join(f"{f:>14s}" for f in fams) + f" {'average':>10s}")
    for m in methods:
        vals = [table[f][m] for f in fams]
        print(f"{m:12s} " + " ".join(f"{v:14.3f}" for v in vals) + f" {np.mean(vals):10.3f}")


def main(quick=True):
    t2, _ = run(quick=quick, metric="block_efficiency")
    print_table(t2, "Table 2 analogue: block efficiency (iid root rollouts, best (K,L))")
    t3, _ = run(quick=quick, metric="throughput")
    # recompute printable TPS values: rows store score = TPS directly
    print_table(t3, "Table 3 analogue: modelled throughput score (Eq. 11 latency)")
    return {"table2": t2, "table3": t3}


# ------------------------------------------- Table-1 cross-verifier matrix ---
#
# Three cell kinds, every one computed for EVERY registered verifier:
#
#   lossless — exact enumeration over draft-tree AND verifier randomness
#              (core/enumerate.py): the composed block law must equal the
#              target process.  Gap is reported; the gate is < 1e-9.
#   block_efficiency — E[tau+1] over s sampled delayed trees per sampling
#              temperature (core/delayed.py registry dispatch), at a matched
#              5-node budget so the columns are comparable.
#   exactness — the serving contract: one batched+pipelined pool engine must
#              emit token-identical outputs to per-request single-stream
#              engines, per verifier, per target-pass strategy (a tree arch
#              and a replay arch from the configs/ zoo); --full adds the
#              2-shard engine.

# matched 5-node tree budgets: multipath (K=2: 1 trunk + 2x2 branches) vs
# single-path (K=1: one path of 5) — and the engine smoke action per kind
MATRIX_BE_ACTION = {True: (2, 1, 2), False: (1, 2, 3)}
MATRIX_ENGINE_ACTION = {True: (2, 1, 1), False: (1, 1, 1)}
MATRIX_ARCHES_QUICK = ["granite-8b", "mamba2-2.7b"]  # one arch per strategy
MATRIX_ARCHES = ["granite-8b", "minitron-8b", "mamba2-2.7b", "recurrentgemma-2b"]
LOSSLESS_GATE = 1e-9


def _registry():
    from repro.core.verify import VERIFIERS

    return sorted(VERIFIERS.items())


def lossless_cases(multipath: bool, quick: bool):
    """(K, L1, L2) enumeration cases; single-path verifiers only see K=1."""
    if not multipath:
        return [(1, 0, 2), (1, 1, 1)] if quick else [(1, 0, 1), (1, 0, 2), (1, 1, 1), (1, 2, 1)]
    return [(2, 1, 1), (2, 0, 2)] if quick else [(2, 0, 1), (2, 1, 1), (2, 1, 2), (3, 0, 2), (1, 0, 2)]


def losslessness_rows(quick: bool, seed: int = 11) -> list[dict]:
    from repro.core.enumerate import RandomModel, expected_block_dist, lossless_gap

    rows = []
    for name, spec in _registry():
        for (K, L1, L2) in lossless_cases(spec.multipath, quick):
            model = RandomModel(3, seed=seed, divergence=0.7)
            bd = expected_block_dist(spec.output_dist, model, K, L1, L2)
            gap = float(lossless_gap(bd, model, L1 + L2 + 1))
            rows.append(dict(cell="lossless", verifier=name, K=K, L1=L1, L2=L2,
                             gap=gap, lossless=bool(gap < LOSSLESS_GATE)))
    return rows


def block_efficiency_rows(quick: bool, s: int = 3, seed: int = 0) -> list[dict]:
    from repro.core.delayed import estimate_block_efficiency

    sampling = SAMPLING_QUICK if quick else SAMPLING
    families = ["llama-9to1"] if quick else list(FAMILIES)
    rows = []
    for family in families:
        for (temp, top_p) in sampling:
            proc = make_process(family, 0, temp, top_p)
            for name, spec in _registry():
                K, L1, L2 = MATRIX_BE_ACTION[spec.multipath]
                rng = np.random.default_rng(seed)  # shared trees per K-class
                be = estimate_block_efficiency(rng, proc.q, proc.p, name, K, L1, L2, s=s)
                rows.append(dict(cell="block_efficiency", verifier=name, family=family,
                                 temp=temp, top_p=top_p, K=K, L1=L1, L2=L2,
                                 block_efficiency=float(be)))
    return rows


def exactness_rows(quick: bool, seed: int = 0, max_new: int = 8) -> list[dict]:
    from dataclasses import replace

    import jax

    from repro.configs import get_smoke
    from repro.launch.serve import make_draft_cfg
    from repro.models.transformer import init_params
    from repro.serving.batch_engine import (
        BatchedSpeculativeEngine,
        ShardedBatchedSpeculativeEngine,
    )
    from repro.serving.engine import EngineConfig, SamplingParams, SpeculativeEngine

    rows = []
    for arch in (MATRIX_ARCHES_QUICK if quick else MATRIX_ARCHES):
        cfg = get_smoke(arch)
        dcfg = make_draft_cfg(cfg)
        tp = init_params(cfg, jax.random.PRNGKey(seed))
        dp = init_params(dcfg, jax.random.PRNGKey(seed + 1))
        prng = np.random.default_rng(seed)
        prompts = [prng.integers(0, cfg.vocab, size=5).tolist() for _ in range(2)]
        seeds = [seed + 100 + i for i in range(len(prompts))]
        sampling = SamplingParams()
        base = EngineConfig(K=2, L1=1, L2=1, max_cache=128, seed=seed)
        # ONE engine pair per arch, re-aimed per verifier: the jit cache is
        # per-engine, and the verifier is host-side state the compiled steps
        # never see — rebuilding per verifier would recompile 11x for nothing
        seq = SpeculativeEngine(cfg, tp, dcfg, dp, base, sampling)
        beng = BatchedSpeculativeEngine(cfg, tp, dcfg, dp, base, sampling,
                                        n_slots=len(prompts), pipeline=True)
        sheng = None
        if not quick and beng.strategy == "tree":
            sheng = ShardedBatchedSpeculativeEngine(
                cfg, tp, dcfg, dp, base, sampling, n_slots=len(prompts),
                data_shards=2)
        for name, spec in _registry():
            K, L1, L2 = MATRIX_ENGINE_ACTION[spec.multipath]
            ecfg = replace(base, verifier=name, K=K, L1=L1, L2=L2)
            seq.ecfg = beng.ecfg = ecfg
            singles = []
            for p, sd in zip(prompts, seeds):
                seq.rng = np.random.default_rng(sd)
                singles.append(seq.generate(list(p), max_new=max_new))
            outs = beng.generate_batch([list(p) for p in prompts], max_new, seeds=seeds)
            exact = singles == outs
            c = beng.counters
            be = c["accepted"] / max(c["blocks"], 1) + 1
            row = dict(cell="exactness", verifier=name, arch=arch,
                       strategy=beng.strategy, K=K, L1=L1, L2=L2,
                       exact=bool(exact), pipelined=True,
                       block_efficiency=float(be))
            if sheng is not None:
                sheng.ecfg = ecfg
                for sh in sheng.shards:
                    sh.ecfg = ecfg
                shouts = sheng.generate_batch([list(p) for p in prompts], max_new, seeds=seeds)
                row["sharded_exact"] = bool(singles == shouts)
            rows.append(row)
            beng.reset_counters(("accepted", "blocks"))
    return rows


def run_matrix(quick: bool = True, json_path: str | None = None, seed: int = 0):
    names = [n for n, _ in _registry()]
    rows = losslessness_rows(quick, seed=seed + 11)
    rows += block_efficiency_rows(quick, seed=seed)
    rows += exactness_rows(quick, seed=seed)

    by_v = {n: {} for n in names}
    for r in rows:
        v = by_v[r["verifier"]]
        if r["cell"] == "lossless":
            v["gap"] = max(v.get("gap", 0.0), r["gap"])
        elif r["cell"] == "block_efficiency":
            v.setdefault("be", []).append(r["block_efficiency"])
        else:
            v["exact"] = v.get("exact", True) and r["exact"] and r.get("sharded_exact", True)
    print(f"\n== Table 1 analogue: verifier matrix ({'quick' if quick else 'full'}) ==")
    print(f"{'verifier':14s} {'worst gap':>12s} {'mean E[tau+1]':>14s} {'engine exact':>13s}")
    for n in names:
        v = by_v[n]
        print(f"{n:14s} {v['gap']:12.2e} {np.mean(v['be']):14.3f} "
              f"{'yes' if v['exact'] else 'NO':>13s}")

    if json_path:
        write_bench_json(
            json_path, "verifier_matrix",
            {"mode": "quick" if quick else "full", "seed": seed,
             "verifiers": names,
             "arches": MATRIX_ARCHES_QUICK if quick else MATRIX_ARCHES,
             "sampling": SAMPLING_QUICK if quick else SAMPLING,
             "be_actions": {str(k): list(v) for k, v in MATRIX_BE_ACTION.items()},
             "engine_actions": {str(k): list(v) for k, v in MATRIX_ENGINE_ACTION.items()},
             "lossless_gate": LOSSLESS_GATE},
            rows)
        print(f"wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--matrix", action="store_true",
                    help="run the Table-1 cross-verifier matrix over the "
                         "whole registry instead of the Table-2/3 sweeps")
    ap.add_argument("--full", action="store_true",
                    help="full grid (weekly tier); default is the quick "
                         "per-PR slice")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the BENCH_verifier_matrix.json document here")
    args = ap.parse_args()
    if args.matrix:
        run_matrix(quick=not args.full, json_path=args.json)
    else:
        main(quick=not args.full)
