"""Tables 2 & 3 — systematic comparison of verification algorithms under
matched i.i.d. root-rollout drafts (L1 = 0), plus the delayed-expansion rows
(the "X, delayed expansion" rows of Tables 8-15).

For each (family x domain x sampling) cell, every verifier picks its best
configuration from the (K, L) grid by the requested metric, exactly as the
paper does ("we select the branching factor K in [1,4] and block length L in
[0,8] that maximizes the block-efficiency or throughput").

Estimation:  OT-based methods use the exact Eq. 3 conditional estimator over
s sampled trees; Traversal/BV/Naive use their exact conditional block-length
laws over the same tree samples.  Verification variance is therefore zero;
only drafting variance remains.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    DOMAINS,
    FAMILIES,
    SAMPLING,
    SAMPLING_QUICK,
    family_latency,
    make_process,
)
from repro.core.delayed import expected_block_efficiency, expected_block_efficiency_traversal
from repro.core.enumerate import mean_block_len
from repro.core.trees import attach_target, build_delayed_tree
from repro.core.verify import verify_topdown_output_dist

OT_METHODS = ["nss", "naivetree", "spectr", "specinfer", "khisti"]
SINGLE_PATH = ["naive", "bv"]  # K = 1 only
ALL_METHODS = OT_METHODS + SINGLE_PATH + ["traversal"]


def block_efficiency(proc, method: str, K: int, L1: int, L2: int, s: int, seed: int) -> float:
    rng = np.random.default_rng(seed)
    vals = []
    for _ in range(s):
        tree = build_delayed_tree(rng, proc.q, K, L1, L2)
        attach_target(tree, proc.p)
        if method == "traversal" or method == "bv":
            vals.append(expected_block_efficiency_traversal(tree))
        elif method == "naive":
            d = verify_topdown_output_dist(tree, "naive")
            vals.append(mean_block_len(d))
        else:
            vals.append(expected_block_efficiency(tree, method))
    return float(np.mean(vals))


def grid_for(method: str, quick: bool, delayed: bool):
    Ks = [1] if method in SINGLE_PATH else ([2, 4] if quick else [1, 2, 3, 4])
    Ls = [2, 4, 6] if quick else [1, 2, 3, 4, 6, 8]
    out = []
    for K in Ks:
        for L in Ls:
            if delayed and K > 1:
                # split the same node budget into trunk + branches
                for L1 in ([1, 2] if quick else [1, 2, 3]):
                    if L - L1 >= 1:
                        out.append((K, L1, L - L1))
            else:
                out.append((K, 0, L))
    return out


def run(quick: bool = True, delayed: bool = False, metric: str = "block_efficiency",
        s: int = 4, seed: int = 0):
    """Returns {family: {method: avg}}, detail rows."""
    sampling = SAMPLING_QUICK if quick else SAMPLING
    domains = DOMAINS[:3] if quick else DOMAINS
    rows = []
    agg: dict = {f: {m: [] for m in ALL_METHODS} for f in FAMILIES}
    for family in FAMILIES:
        lat = family_latency(family)
        for domain in domains:
            for (temp, top_p) in sampling:
                proc = make_process(family, domain, temp, top_p)
                for method in ALL_METHODS:
                    best = -1.0
                    best_a = None
                    for (K, L1, L2) in grid_for(method, quick, delayed):
                        be = block_efficiency(proc, method, K, L1, L2, s, seed)
                        score = be if metric == "block_efficiency" else be / lat.action_time(256, K, L1, L2)
                        if score > best:
                            best, best_a = score, (K, L1, L2)
                    agg[family][method].append(best)
                    rows.append(dict(family=family, domain=domain, temp=temp, top_p=top_p,
                                     method=method, score=best, action=best_a))
    table = {f: {m: float(np.mean(v)) for m, v in d.items()} for f, d in agg.items()}
    return table, rows


def print_table(table: dict, title: str):
    methods = sorted(next(iter(table.values())), key=lambda m: np.mean([table[f][m] for f in table]))
    print(f"\n== {title} ==")
    fams = list(table)
    print(f"{'method':12s} " + " ".join(f"{f:>14s}" for f in fams) + f" {'average':>10s}")
    for m in methods:
        vals = [table[f][m] for f in fams]
        print(f"{m:12s} " + " ".join(f"{v:14.3f}" for v in vals) + f" {np.mean(vals):10.3f}")


def main(quick=True):
    t2, _ = run(quick=quick, metric="block_efficiency")
    print_table(t2, "Table 2 analogue: block efficiency (iid root rollouts, best (K,L))")
    t3, _ = run(quick=quick, metric="throughput")
    # recompute printable TPS values: rows store score = TPS directly
    print_table(t3, "Table 3 analogue: modelled throughput score (Eq. 11 latency)")
    return {"table2": t2, "table3": t3}


if __name__ == "__main__":
    main(quick=True)
