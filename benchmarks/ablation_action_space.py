"""Ablation: the (K, L1, L2) action surface.

For one family/sampling cell, sweep the full action grid and report block
efficiency (Eq. 3, exact inner expectation), Eq.-11 time, and TPS — the
landscape the NDE selector navigates.  Shows (a) block efficiency is
monotone in every axis, (b) TPS is the U-curve the paper describes, and
(c) where the trunk/branch split pays.

    PYTHONPATH=src:. python -m benchmarks.ablation_action_space
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import family_latency, make_process
from repro.core.delayed import estimate_block_efficiency


def run(family="qwen-64to1", temp=0.8, method="specinfer", s=12, seed=0):
    proc = make_process(family, 2, temp, 1.0)
    lat = family_latency(family)
    rng = np.random.default_rng(seed)
    rows = []
    for K in (1, 2, 3, 4):
        for L1 in (0, 1, 2, 4):
            for L2 in (0, 1, 2, 4):
                if L1 + L2 == 0 or (K > 1 and L2 == 0):
                    continue
                be = estimate_block_efficiency(rng, proc.q, proc.p, method, K, L1, L2, s=s)
                t = lat.action_time(256, K, L1, L2)
                rows.append(dict(K=K, L1=L1, L2=L2, be=be, t=t, tps=be / t))
    return rows


def main():
    rows = run()
    rows.sort(key=lambda r: -r["tps"])
    print(f"{'K':>2s} {'L1':>3s} {'L2':>3s} {'nodes':>6s} {'E[tau+1]':>9s} {'T_ms':>8s} {'TPS':>9s}")
    for r in rows[:12]:
        n = r["L1"] + r["K"] * r["L2"]
        print(f"{r['K']:2d} {r['L1']:3d} {r['L2']:3d} {n:6d} {r['be']:9.3f} {r['t']*1e3:8.2f} {r['tps']:9.1f}")
    print("...")
    for r in rows[-4:]:
        n = r["L1"] + r["K"] * r["L2"]
        print(f"{r['K']:2d} {r['L1']:3d} {r['L2']:3d} {n:6d} {r['be']:9.3f} {r['t']*1e3:8.2f} {r['tps']:9.1f}")
    # U-curve check: the best TPS action is neither the smallest nor largest tree
    sizes = [r["L1"] + r["K"] * r["L2"] for r in rows]
    best_n = rows[0]["L1"] + rows[0]["K"] * rows[0]["L2"]
    print(f"\nbest action: K={rows[0]['K']} L1={rows[0]['L1']} L2={rows[0]['L2']} "
          f"({best_n} nodes; grid spans {min(sizes)}-{max(sizes)}) — the paper's U-curve")
    return rows


if __name__ == "__main__":
    main()
