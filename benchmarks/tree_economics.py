"""Speculative-decoding economics on the roofline (the paper's mechanism,
measured on the compiled TPU artifact).

Lowers the tree-verification serve step (T tree tokens, ancestor mask) for a
target architecture at several T and compares its roofline terms with the
1-token decode step.  Decode is memory-bound: weights + KV dominate, and they
are read ONCE regardless of T — so the tree pass is nearly free until the
compute term catches the memory term.  The crossover T* bounds how large a
draft tree is worth verifying, which is exactly the budget the (K, L1, L2)
selector trades against block efficiency.

    PYTHONPATH=src:. python -m benchmarks.tree_economics --arch qwen2-72b
"""
from __future__ import annotations

import argparse
import json


PEAK = 197e12
HBM = 819e9
LINK = 50e9


def lower_tree_step(arch: str, shape: str, T: int, dryrun, cfg_override=None):
    """Lower a tree-verify step with a chain-of-T ancestor mask (worst case)."""
    import jax
    import jax.numpy as jnp
    from functools import partial

    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES, input_specs
    from repro.launch.sharding import cache_shardings, param_shardings
    from repro.models import act_sharding
    from repro.models.transformer import forward, init_params
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_production_mesh()
    cfg0 = cfg_override if cfg_override is not None else get_config(arch)
    kind, kw, cfg = input_specs(cfg0, shape)
    assert kind == "decode"
    B = SHAPES[shape]["batch"]

    def tree_step(params, cache, tokens, anc):
        logits, new_cache, _ = forward(params, cfg, tokens, mode="tree", cache=cache, anc=anc)
        return logits, new_cache

    params_shapes = jax.eval_shape(partial(init_params, cfg), jax.random.PRNGKey(0))
    p_sh = param_shardings(mesh, params_shapes, cfg, mode="serve")
    c_sh = cache_shardings(mesh, kw["cache"], batch_sharded=B > 1)
    tok_sh = NamedSharding(mesh, P("data") if B % 16 == 0 else P())
    toks = jax.ShapeDtypeStruct((B, T), jnp.int32)
    anc = jax.ShapeDtypeStruct((T, T), jnp.bool_)
    jitted = jax.jit(tree_step, in_shardings=(p_sh, c_sh, tok_sh, NamedSharding(mesh, P())))
    with mesh, act_sharding.activation_sharding(mesh, ("data",)):
        compiled = jitted.lower(params_shapes, kw["cache"], toks, anc).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = dryrun.collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "hbm_bytes": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes": float(sum(coll.values())),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--Ts", default="1,4,8,16,32")
    ap.add_argument("--out", default="results/tree_economics.json")
    args = ap.parse_args(argv)

    from repro.launch import dryrun
    from repro.configs import get_config

    cfg = get_config(args.arch)
    L = cfg.n_layers
    rows = []
    print(f"{'T':>4s} {'compute_ms':>11s} {'memory_ms':>10s} {'coll_ms':>8s} {'step_ms(max)':>12s} {'ms/token @BE=T':>15s}")
    for T in [int(t) for t in args.Ts.split(",")]:
        # unrolled 1/2-layer variants + linear extrapolation (XLA counts scan
        # bodies once — same methodology as benchmarks/roofline.py)
        f1 = lower_tree_step(args.arch, args.shape, T, dryrun,
                             cfg_override=cfg.replace(n_layers=1, scan=False))
        f2 = lower_tree_step(args.arch, args.shape, T, dryrun,
                             cfg_override=cfg.replace(n_layers=2, scan=False))
        m = {k: f1[k] + (L - 1) * (f2[k] - f1[k]) for k in f1}
        ct, mt, lt = m["flops"] / PEAK, m["hbm_bytes"] / HBM, m["collective_bytes"] / LINK
        step = max(ct, mt, lt)
        rows.append({"T": T, "compute_s": ct, "memory_s": mt, "collective_s": lt, **m})
        print(f"{T:4d} {ct*1e3:11.3f} {mt*1e3:10.3f} {lt*1e3:8.3f} {step*1e3:12.3f} {step/T*1e3:15.3f}")
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
