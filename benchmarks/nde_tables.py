"""Tables 4-7 analogues — the neural delayed-expansion (NDE) selector.

Offline policy training and evaluation exactly per Sec. 6 / App. E:

  1. For each (family x sampling) setting, label roots along synthetic target
     trajectories with E^[tau+1] (Eq. 3, s trees) and T^ (Eq. 11) per action.
  2. Train the MLP selector on the Eq. 12 objective (scalar features; the
     engine path additionally feeds hidden states — see examples/).
  3. Evaluate on held-out roots: NDE ratio vs the best static action
     (Tables 4-5) and NDE methods vs Traversal (Tables 6-7).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAMILIES, SAMPLING_QUICK, family_latency, make_process
from benchmarks.verifier_tables import block_efficiency
from repro.core.selector import (
    FixedSpace,
    SelectorConfig,
    make_scalar_features,
    selector_logits,
)
from repro.training.selector_train import train_selector

ACTIONS = [
    (1, 2, 0), (1, 4, 0), (1, 6, 0),
    (2, 0, 2), (2, 1, 2), (2, 2, 2), (2, 3, 2),
    (3, 1, 2), (3, 2, 1),
    (4, 0, 2), (4, 2, 1), (4, 2, 2),
]
# Traversal is the *existing-method* baseline: i.i.d. root rollouts with a
# static best (K, L) per setting (the paper's Sec. 4 protocol) — delayed
# trees and the neural selector are what this paper adds to the OT methods.
TRAVERSAL_ACTIONS = [(K, 0, L) for K in (1, 2, 3, 4) for L in (2, 4, 6, 8)]
NDE_METHODS = ["nss", "naivetree", "spectr", "specinfer", "khisti"]


def _root_features(proc, ctx, lat, temp, top_p):
    p = proc.p(ctx)
    q = proc.q(ctx)
    return make_scalar_features(p, q, q, len(ctx) + 256, temp, top_p,
                                lat.t_q(len(ctx) + 256), lat.t_p(len(ctx) + 256))


def collect(proc, method, lat, temp, top_p, n_roots, s, seed, actions=ACTIONS):
    rng = np.random.default_rng(seed)
    feats, effs, times = [], [], []
    for _ in range(n_roots):
        ctx = tuple(rng.integers(0, proc.vocab, size=int(rng.integers(0, 5))))
        feats.append(_root_features(proc, ctx, lat, temp, top_p))
        e_row, t_row = [], []
        for (K, L1, L2) in actions:
            e_row.append(block_efficiency(proc, method, K, L1, L2, s,
                                          int(rng.integers(2**31))))
            t_row.append(lat.action_time(len(ctx) + 256, K, L1, L2))
        effs.append(e_row)
        times.append(t_row)
    Hq = 16
    z = np.zeros((len(feats), Hq), np.float32)
    return {
        "h_prev_p": z, "h_prev_q": z, "h_cur_q": z,
        "scalars": np.stack(feats).astype(np.float32),
        "eff": np.asarray(effs, np.float32),
        "time": np.asarray(times, np.float32),
    }


def eval_policy(params, scfg, traces, mu, sd):
    sc = (traces["scalars"] - mu) / sd
    logits = selector_logits(
        params,
        jnp.asarray(traces["h_prev_p"]), jnp.asarray(traces["h_prev_q"]),
        jnp.asarray(traces["h_cur_q"]), jnp.asarray(sc),
    )
    a = np.asarray(jnp.argmax(logits, axis=-1))
    idx = np.arange(len(a))
    tps = traces["eff"][idx, a] / traces["time"][idx, a]
    be = traces["eff"][idx, a]
    return float(np.mean(tps)), float(np.mean(be))


def run(quick: bool = True, seed: int = 0):
    n_roots = 24 if quick else 80
    s = 2 if quick else 4
    steps = 150 if quick else 400
    sampling = SAMPLING_QUICK[:2] if quick else SAMPLING_QUICK
    out: dict = {"t4": {}, "t5": {}, "t6": {}, "t7": {}, "oracle": {}}
    for family in FAMILIES:
        lat = family_latency(family)
        for method in NDE_METHODS + ["traversal"]:
            tps_nde, be_nde, tps_base, be_base = [], [], [], []
            for (temp, top_p) in sampling:
                proc = make_process(family, 1, temp, top_p)
                acts = TRAVERSAL_ACTIONS if method == "traversal" else ACTIONS
                tr = collect(proc, method, lat, temp, top_p, n_roots, s, seed, actions=acts)
                te = collect(proc, method, lat, temp, top_p, max(n_roots // 2, 8), s, seed + 1,
                             actions=acts)
                if method == "traversal":
                    # Traversal has no NDE in the paper; report its best static
                    tps_rows = tr["eff"] / tr["time"]
                    b = int(np.argmax(tps_rows.mean(axis=0)))
                    tps_base.append(float((te["eff"][:, b] / te["time"][:, b]).mean()))
                    be_base.append(float(te["eff"][:, b].mean()))
                    continue
                scfg = SelectorConfig(hidden_p=16, hidden_q=16, dropout=0.05,
                                      space=FixedSpace(ACTIONS))
                params, _ = train_selector(tr, scfg, steps=steps, batch=16, seed=seed,
                                           lam=0.3, cvar_alpha=0.25)
                mu = tr["scalars"].mean(0, keepdims=True)
                sd = tr["scalars"].std(0, keepdims=True) + 1e-6
                tps, be = eval_policy(params, scfg, te, mu, sd)
                tps_rows = tr["eff"] / tr["time"]
                b = int(np.argmax(tps_rows.mean(axis=0)))
                tps_nde.append(tps)
                be_nde.append(be)
                tps_base.append(float((te["eff"][:, b] / te["time"][:, b]).mean()))
                be_base.append(float(te["eff"][:, b].mean()))
                # per-root oracle (context-dependence headroom)
                tps_te = te["eff"] / te["time"]
                out.setdefault("oracle", {}).setdefault(method, {}).setdefault(family, []).append(
                    float(tps_te.max(axis=1).mean())
                )
            if method == "traversal":
                out["t6"].setdefault("traversal", {})[family] = float(np.mean(be_base))
                out["t7"].setdefault("traversal", {})[family] = float(np.mean(tps_base))
            else:
                out["t4"].setdefault(method, {})[family] = float(np.mean(be_nde) / np.mean(be_base))
                out["t5"].setdefault(method, {})[family] = float(np.mean(tps_nde) / np.mean(tps_base))
                out["t6"].setdefault(f"{method}-nde", {})[family] = float(np.mean(be_nde))
                out["t7"].setdefault(f"{method}-nde", {})[family] = float(np.mean(tps_nde))
    out["oracle"] = {
        m: {f: float(np.mean(v)) for f, v in d.items()} for m, d in out["oracle"].items()
    }
    return out


def print_tables(out):
    for key, title in [("t4", "Table 4: NDE block-efficiency ratio vs static baseline"),
                       ("t5", "Table 5: NDE throughput ratio vs static baseline"),
                       ("t6", "Table 6: block efficiency — NDE methods vs Traversal"),
                       ("t7", "Table 7: throughput — NDE methods vs Traversal")]:
        tab = out[key]
        fams = list(FAMILIES)
        print(f"\n== {title} ==")
        print(f"{'method':16s} " + " ".join(f"{f:>14s}" for f in fams) + f" {'average':>10s}")
        for m, d in sorted(tab.items(), key=lambda kv: np.mean(list(kv[1].values()))):
            vals = [d[f] for f in fams]
            print(f"{m:16s} " + " ".join(f"{v:14.3f}" for v in vals) + f" {np.mean(vals):10.3f}")


def main(quick=True):
    out = run(quick=quick)
    print_tables(out)
    return out


if __name__ == "__main__":
    main()
