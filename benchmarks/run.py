"""Benchmark driver.  One function per paper table/figure, plus core-op
microbenchmarks.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src:. python -m benchmarks.run [--full]

The roofline sweep (needs the 512-device dry-run env) runs separately:
    PYTHONPATH=src:. python -m benchmarks.roofline --out results/roofline.json
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _time(fn, n=20, warmup=2):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us


def micro_rows():
    """Core-op microbenchmarks (CPU walltime; TPU numbers come from the
    roofline terms, not from this container)."""
    import jax
    import jax.numpy as jnp

    from repro.core.otlp import OTLP_SOLVERS
    from repro.core.traversal import verify_traversal
    from repro.core.trees import attach_target, build_delayed_tree
    from repro.kernels.ops import gqa_decode_attention, gqa_tree_attention
    from benchmarks.common import make_process

    rows = []
    rng = np.random.default_rng(0)
    proc = make_process("llama-9to1", 0, 1.0, 1.0)
    p = proc.p(())
    q = proc.q(())
    xs = [1, 3]
    for name in ["naive", "nss", "spectr", "specinfer", "khisti"]:
        solve, output_dist, _ = OTLP_SOLVERS[name]
        us = _time(lambda: output_dist(p, q, xs), n=200)
        rows.append((f"otlp_output_dist_{name}", us, f"V={len(p)},k=2"))
    tree = attach_target(build_delayed_tree(rng, proc.q, 2, 2, 2), proc.p)
    us = _time(lambda: verify_traversal(tree, rng), n=100)
    rows.append(("verify_traversal", us, "K2,L1=2,L2=2"))

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    qq = jax.random.normal(ks[0], (1, 8, 4, 128), jnp.float32)
    kk = jax.random.normal(ks[1], (1, 256, 2, 128), jnp.float32)
    vv = jax.random.normal(ks[2], (1, 256, 2, 128), jnp.float32)
    mm = jax.random.bernoulli(ks[3], 0.7, (1, 8, 256))
    out = gqa_tree_attention(qq, kk, vv, mm, block_k=128, interpret=True)
    jax.block_until_ready(out)
    us = _time(lambda: jax.block_until_ready(
        gqa_tree_attention(qq, kk, vv, mm, block_k=128, interpret=True)), n=5)
    rows.append(("pallas_tree_attention_interpret", us, "T8,S256,H4"))
    q1 = jax.random.normal(ks[0], (1, 1, 4, 128), jnp.float32)
    ln = jnp.asarray([250], jnp.int32)
    out = gqa_decode_attention(q1, kk, vv, ln, block_k=128, interpret=True)
    jax.block_until_ready(out)
    us = _time(lambda: jax.block_until_ready(
        gqa_decode_attention(q1, kk, vv, ln, block_k=128, interpret=True)), n=5)
    rows.append(("pallas_decode_attention_interpret", us, "S256,H4"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale settings (slow)")
    ap.add_argument("--out", default="results/bench.json")
    args = ap.parse_args(argv)
    quick = not args.full

    results = {}
    print("name,us_per_call,derived")

    t0 = time.time()
    from benchmarks.verifier_tables import run as run_tables

    t2, _ = run_tables(quick=quick, metric="block_efficiency", s=2 if quick else 4)
    results["table2"] = t2
    avg = {m: float(np.mean([t2[f][m] for f in t2])) for m in next(iter(t2.values()))}
    print(f"table2_block_efficiency,{(time.time()-t0)*1e6:.0f},"
          f"traversal={avg['traversal']:.3f};specinfer={avg['specinfer']:.3f};nss={avg['nss']:.3f}")

    t0 = time.time()
    t3, _ = run_tables(quick=quick, metric="throughput", s=2 if quick else 4)
    results["table3"] = t3
    avg3 = {m: float(np.mean([t3[f][m] for f in t3])) for m in next(iter(t3.values()))}
    best3 = max(avg3, key=avg3.get)
    print(f"table3_throughput,{(time.time()-t0)*1e6:.0f},best={best3}:{avg3[best3]:.2f}")

    t0 = time.time()
    from benchmarks.fig1_acceptance_depth import run as run_fig1

    acc, l1 = run_fig1(quick=quick)
    results["fig1"] = {"l1": list(map(float, l1))}
    print(f"fig1_acceptance_depth,{(time.time()-t0)*1e6:.0f},"
          f"l1_d0={l1[0]:.3f};l1_d6={l1[-1]:.3f};spectr_drop={acc['spectr'][0]-acc['spectr'][-1]:.3f}")

    t0 = time.time()
    from benchmarks.nde_tables import run as run_nde

    nde = run_nde(quick=quick)
    results.update({k: v for k, v in nde.items()})
    t5avg = {m: float(np.mean(list(d.values()))) for m, d in nde["t5"].items()}
    t7avg = {m: float(np.mean(list(d.values()))) for m, d in nde["t7"].items()}
    si = t7avg.get("specinfer-nde", 0.0)
    tv = t7avg.get("traversal", 1.0)
    print(f"table45_nde_ratio,{(time.time()-t0)*1e6:.0f},tps_ratio_avg={np.mean(list(t5avg.values())):.3f}")
    print(f"table67_nde_vs_traversal,0,specinfer_nde/traversal={si/tv:.3f}")

    for name, us, derived in micro_rows():
        print(f"{name},{us:.1f},{derived}")

    # attach roofline summary if present
    try:
        with open("results/roofline.json") as f:
            rl = json.load(f)
        ok = [r for r in rl if "dominant" in r]
        doms: dict = {}
        for r in ok:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        print(f"roofline_summary,0,pairs={len(ok)};" + ";".join(f"{k}={v}" for k, v in doms.items()))
        results["roofline_dominants"] = doms
    except FileNotFoundError:
        pass

    import os

    os.makedirs("results", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=float)
    return results


if __name__ == "__main__":
    main()
