"""Figure 1 analogue — OTLP acceptance rates and target-draft L1 distance by
draft-tree depth.

The paper generates 200k+ trees along target trajectories; here roots are
drawn along synthetic target trajectories and acceptance (Def. 5.1 / App. C)
is evaluated with the exact closed forms at every node depth.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_process
from repro.core.otlp import acceptance_rate

SOLVERS = ["naive", "nss", "spectr", "specinfer", "khisti"]


def run(max_depth: int = 6, n_roots: int = 200, k: int = 2, family: str = "llama-9to1",
        quick: bool = True):
    if quick:
        n_roots = 60
    rows = {s: np.zeros(max_depth + 1) for s in SOLVERS}
    l1 = np.zeros(max_depth + 1)
    counts = np.zeros(max_depth + 1)
    rng = np.random.default_rng(0)
    proc = make_process(family, 0, 1.0, 1.0)
    for root in range(n_roots):
        # walk a target trajectory to a random root, then descend a drafted path
        ctx = tuple(rng.integers(0, proc.vocab, size=rng.integers(0, 4)))
        for d in range(max_depth + 1):
            p, q = proc.p(ctx), proc.q(ctx)
            for s in SOLVERS:
                rows[s][d] += acceptance_rate(s, p, q, k)
            l1[d] += np.abs(p - q).sum()
            counts[d] += 1
            ctx = ctx + (int(rng.choice(proc.vocab, p=q)),)  # drafted continuation
    for s in SOLVERS:
        rows[s] /= counts
    l1 /= counts
    return rows, l1


def main(quick=True):
    rows, l1 = run(quick=quick)
    print("\n== Fig. 1 analogue: acceptance rate by depth (k=2) ==")
    depths = range(len(l1))
    print(f"{'depth':>6s} " + " ".join(f"{s:>10s}" for s in SOLVERS) + f" {'L1(p,q)':>10s}")
    for d in depths:
        print(f"{d:6d} " + " ".join(f"{rows[s][d]:10.4f}" for s in SOLVERS) + f" {l1[d]:10.4f}")
    # the paper's finding: acceptance decreases with depth as L1 grows
    for s in SOLVERS:
        assert rows[s][0] > rows[s][-1], f"{s}: acceptance did not decay with depth"
    assert l1[-1] > l1[0]
    print("(acceptance decays with depth; L1 divergence grows — Fig. 1 reproduced)")
    return {"acceptance": {s: rows[s].tolist() for s in SOLVERS}, "l1": l1.tolist()}


if __name__ == "__main__":
    main()
