"""Shared benchmark infrastructure.

Synthetic (p, q) processes stand in for the paper's model/dataset grid: the
verification algorithms consume only per-node next-token distributions, so a
table-driven process exercises exactly the same code while staying CPU-cheap.

  * families  — target:draft size-ratio analogues (the paper's Qwen ~64:1,
    Gemma ~100:1, Llama ~9:1) realised as base divergence levels + a
    depth-growth coefficient (the Fig. 1 mechanism).
  * domains   — dataset analogues (seeds; math/code/writing/translation
    differ only through the induced (p, q) statistics here).
  * sampling  — the paper's 8 configurations: temperatures at top_p = 1 and
    nucleus settings at temperature 1.

The latency model (Eq. 11) is calibrated from the TPU roofline of the paper's
own Llama-3 70B/8B pair (197 TFLOP/s bf16, 819 GB/s HBM per chip) — see
``analytic_latency``.
"""
from __future__ import annotations

import zlib

import numpy as np

from repro.core.delayed import LatencyModel

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


class SyntheticProcess:
    """Deterministic per-context (p, q) tables with controllable divergence
    growth in depth and sampling-parameter warping."""

    def __init__(self, vocab: int, seed: int, base_div: float, depth_div: float,
                 temperature: float = 1.0, top_p: float = 1.0, concentration: float = 0.6):
        self.vocab = vocab
        self.seed = seed
        self.base_div = base_div
        self.depth_div = depth_div
        self.temperature = temperature
        self.top_p = top_p
        self.concentration = concentration
        self._cache: dict = {}

    def _warp(self, d):
        if self.temperature != 1.0:
            d = np.power(np.clip(d, 1e-12, None), 1.0 / self.temperature)
            d = d / d.sum()
        if self.top_p < 1.0:
            order = np.argsort(d)[::-1]
            cs = np.cumsum(d[order])
            keep_n = int(np.searchsorted(cs, self.top_p) + 1)
            mask = np.zeros_like(d, dtype=bool)
            mask[order[:keep_n]] = True
            d = np.where(mask, d, 0.0)
            d = d / d.sum()
        return d

    def _dists(self, ctx):
        if ctx not in self._cache:
            rng = np.random.default_rng(zlib.crc32(repr(("sp", self.seed, ctx)).encode()))
            # per-region modulation: different trajectory regions have
            # different draft alignment AND different peakedness (easy
            # low-entropy spans accept deep blocks; hard flat spans don't) —
            # the context-dependence the NDE selector exploits (Sec. 6).
            # Both are functions of the region key, so root-level entropy/KL
            # features are predictive of downstream acceptance.
            region = np.random.default_rng(zlib.crc32(repr(("mod", self.seed, ctx[:1])).encode()))
            mod = region.uniform(-0.25, 0.35)
            conc = self.concentration * region.uniform(0.25, 3.0)
            p = rng.dirichlet(np.full(self.vocab, conc))
            noise = rng.dirichlet(np.full(self.vocab, conc))
            w = float(np.clip(self.base_div + mod + self.depth_div * len(ctx), 0.02, 0.97))
            q = (1 - w) * p + w * noise
            # the paper warps the TARGET sampling distribution; the draft
            # proposes from its own (warped) head as engines do
            self._cache[ctx] = (self._warp(p), self._warp(q))
        return self._cache[ctx]

    def p(self, ctx):
        return self._dists(tuple(ctx))[0]

    def q(self, ctx):
        return self._dists(tuple(ctx))[1]


# paper-analogue grid
FAMILIES = {
    # name: (base divergence, depth growth)  ~ target:draft ratio analogue
    "qwen-64to1": (0.35, 0.10),
    "gemma-100to1": (0.55, 0.15),
    "llama-9to1": (0.15, 0.06),
}
DOMAINS = [0, 1, 2, 3, 4]  # math-e, math-h, code, writing, translation analogues
SAMPLING = [
    (0.2, 1.0), (0.4, 1.0), (0.6, 1.0), (0.8, 1.0), (1.0, 1.0), (1.2, 1.0),
    (1.0, 0.9), (1.0, 0.99),
]
SAMPLING_QUICK = [(0.2, 1.0), (0.6, 1.0), (1.0, 1.0), (1.0, 0.9)]


def make_process(family: str, domain: int, temperature: float, top_p: float,
                 vocab: int = 8) -> SyntheticProcess:
    b, g = FAMILIES[family]
    return SyntheticProcess(vocab, seed=1000 * DOMAINS.index(domain) + zlib.crc32(family.encode()) % 997,
                            base_div=b, depth_div=g, temperature=temperature, top_p=top_p)


def analytic_latency(n_params_target: float, n_params_draft: float,
                     kv_bytes_per_tok_t: float, kv_bytes_per_tok_d: float,
                     chips: int = 8, overhead: float = 20e-6,
                     tree_tok_frac: float = 0.02) -> LatencyModel:
    """Decode-step latency from the roofline (memory-bound regime):
    t(l) = overhead + (2*N + l*kv)/HBM_BW/chips.  Matches Eq. 11's affine
    form; the paper instead microbenchmarks — see DESIGN.md.  tree_tok_frac
    is the measured marginal target-pass cost per speculation token
    (benchmarks/tree_economics.py)."""
    t_p_base = overhead + 2 * n_params_target / (HBM_BW * chips)
    return LatencyModel(
        t_q_base=overhead + 2 * n_params_draft / (HBM_BW * chips),
        t_q_per_tok=kv_bytes_per_tok_d / (HBM_BW * chips),
        t_p_base=t_p_base,
        t_p_per_tok=kv_bytes_per_tok_t / (HBM_BW * chips),
        t_p_per_tree_tok=tree_tok_frac * t_p_base,
    )


def paper_pair_latency(chips: int = 8) -> LatencyModel:
    """Llama-3 70B / 8B decode latency on `chips` v5e chips."""
    from repro.configs.paper_llama70b_8b import DRAFT, TARGET

    kv_t = TARGET.n_layers * 2 * TARGET.n_kv_heads * TARGET.hd * 2
    kv_d = DRAFT.n_layers * 2 * DRAFT.n_kv_heads * DRAFT.hd * 2
    return analytic_latency(TARGET.param_count(), DRAFT.param_count(), kv_t, kv_d, chips)


FAMILY_LATENCY = {
    # scale draft size by the family ratio analogue
    "qwen-64to1": (32e9, 0.5e9),
    "gemma-100to1": (27e9, 0.27e9),
    "llama-9to1": (70e9, 8e9),
}


def family_latency(family: str, chips: int = 8) -> LatencyModel:
    nt, nd = FAMILY_LATENCY[family]
    return analytic_latency(nt, nd, nt / 4e6, nd / 4e6, chips)


# ------------------------------------------------------- bench JSON schema ---

BENCH_SCHEMA = 1


def write_bench_json(path: str, name: str, config: dict, results: list[dict]) -> dict:
    """Emit a bench run as the stable machine-readable ``BENCH_<name>.json``
    document the regression gate (scripts/bench_smoke.sh) and the checked-in
    baselines (benchmarks/baselines/) consume:

        {"bench": <name>, "schema": BENCH_SCHEMA,
         "config": {...flags of the run...},
         "results": [ {...one row per measured point...} ]}

    ``config`` holds the knobs that define the run (arch, verifier, action,
    sizes); each ``results`` row holds the measured numbers for one point
    (tokens/sec per mode, commit_ms, blocks peak, exactness booleans).  The
    writer is schema-versioned so gates can refuse documents they do not
    understand instead of misreading them.
    """
    import json

    doc = {"bench": name, "schema": BENCH_SCHEMA, "config": config, "results": results}
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc
