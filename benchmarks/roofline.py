"""Roofline analysis (deliverable g).

For every (arch x shape) on the single-pod 16x16 mesh:

    compute term    = HLO_FLOPs_dev / peak_FLOPs          (197 TFLOP/s bf16)
    memory term     = HLO_bytes_dev / HBM_bw              (819 GB/s)
    collective term = collective_bytes_dev / link_bw      (~50 GB/s/link)

XLA's cost analysis counts while-loop bodies ONCE (trip counts ignored), so
scanned-layer lowerings undercount by ~n_layers.  We therefore lower
*unrolled* reduced-depth variants (1 and 2 layer-units) and extrapolate
linearly — exact for homogeneous stacks:

    metric(L) = f(1) + (L - 1) * (f(2) - f(1))            [+ tail for hybrid]

Collective bytes come from the partitioned HLO text (per-device operand
shapes), so all three terms are per-device.  MODEL_FLOPS uses 6*N_active*D
(train) / 2*N_active*D (inference) for the useful-compute ratio.

Run:  PYTHONPATH=src:. python -m benchmarks.roofline --out results/roofline.json
(needs the 512-device dry-run environment; imports repro.launch.dryrun first.)
"""
from __future__ import annotations

import argparse
import json


PEAK = 197e12
HBM = 819e9
LINK = 50e9
CHIPS = 256


def _units(cfg):
    """(unit kind, total units, variant builder)."""
    if cfg.arch_type == "hybrid":
        g = cfg.hybrid_attn_every
        ngroups, rem = divmod(cfg.n_layers, g)
        return "group", ngroups, rem
    if cfg.arch_type == "moe" and cfg.moe_every > 1:
        return "macro", cfg.n_layers // cfg.moe_every, 0
    return "layer", cfg.n_layers, 0


def _variant(cfg, n_units: int, with_tail: bool = False):
    kw = {"scan": False}
    if cfg.arch_type == "hybrid":
        kw["n_layers"] = n_units * cfg.hybrid_attn_every + (2 if with_tail else 0)
    elif cfg.arch_type == "moe" and cfg.moe_every > 1:
        kw["n_layers"] = n_units * cfg.moe_every
    else:
        kw["n_layers"] = n_units
        if cfg.arch_type == "encdec":
            kw["n_enc_layers"] = n_units
    return cfg.replace(**kw)


def measure(arch: str, shape: str, lower_one) -> dict:
    """Extrapolated per-device HLO metrics for the full config."""
    from repro.configs import get_config

    cfg = get_config(arch)
    kind, total, rem = _units(cfg)

    def run(cfg_v):
        return lower_one(arch, shape, multi_pod=False, compile_=True, cfg_override=cfg_v)

    f1 = run(_variant(cfg, 1))
    f2 = run(_variant(cfg, 2))

    def metric(name):
        a, b = f1.get(name, 0.0), f2.get(name, 0.0)
        return a + (total - 1) * (b - a)

    def coll(name):
        a = f1["collectives"].get(name, 0)
        b = f2["collectives"].get(name, 0)
        return max(a + (total - 1) * (b - a), 0)

    out = {
        "flops_dev": metric("flops"),
        "bytes_dev": metric("hbm_bytes"),
        "collectives": {k: coll(k) for k in f1["collectives"]},
        "unit_kind": kind,
        "units": total,
    }
    if rem:  # hybrid tail: 2 extra recurrent layers measured directly
        f1t = run(_variant(cfg, 1, with_tail=True))
        out["flops_dev"] += max(f1t["flops"] - f1["flops"], 0.0)
        out["bytes_dev"] += max(f1t["hbm_bytes"] - f1["hbm_bytes"], 0.0)
        for k in out["collectives"]:
            out["collectives"][k] += max(
                f1t["collectives"].get(k, 0) - f1["collectives"].get(k, 0), 0
            )
    out["collective_bytes_dev"] = float(sum(out["collectives"].values()))
    return out


def model_flops(cfg, shape: str) -> float:
    from repro.launch.shapes import SHAPES

    spec = SHAPES[shape]
    n = cfg.active_param_count()
    if spec["kind"] == "train":
        return 6.0 * n * spec["batch"] * spec["seq"]
    if spec["kind"] == "prefill":
        return 2.0 * n * spec["batch"] * spec["seq"]
    return 2.0 * n * spec["batch"]  # decode: one token per request


def improvement_hint(dom: str, cfg, shape: str) -> str:
    if dom == "collective":
        if cfg.arch_type == "moe":
            return "overlap all-to-all with expert compute; widen expert sharding groups"
        if cfg.arch_type == "hybrid":
            return "shard RG-LRU gates block-diagonally to kill the gate all-reduces"
        return "reduce-scatter the FSDP all-gathers; fuse collectives across layers"
    if dom == "memory":
        if "decode" in shape or shape == "long_500k":
            return "decode is weight/KV-bound: quantize KV, raise batch, or speculate more tokens per pass (this paper)"
        return "recompute less (selective remat) or fuse elementwise chains"
    return "raise arithmetic intensity: larger microbatch per device or fused matmuls"


def analyse(measured: dict, cfg, shape: str) -> dict:
    ct = measured["flops_dev"] / PEAK
    mt = measured["bytes_dev"] / HBM
    lt = measured["collective_bytes_dev"] / LINK
    dom = max((("compute", ct), ("memory", mt), ("collective", lt)), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape) / CHIPS
    return {
        "compute_s": ct,
        "memory_s": mt,
        "collective_s": lt,
        "dominant": dom,
        "model_flops_dev": mf,
        "useful_ratio": mf / measured["flops_dev"] if measured["flops_dev"] else 0.0,
        "hint": improvement_hint(dom, cfg, shape),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="results/roofline.json")
    args = ap.parse_args(argv)

    # dryrun import sets XLA_FLAGS before jax loads
    from repro.launch import dryrun
    from repro.configs import get_config, list_arches
    from repro.launch.shapes import SHAPES

    arches = [args.arch] if args.arch else list_arches()
    shapes = [args.shape] if args.shape else list(SHAPES)
    rows = []
    for arch in arches:
        cfg = get_config(arch)
        for shape in shapes:
            try:
                m = measure(arch, shape, dryrun.lower_one)
                a = analyse(m, cfg, shape)
                rows.append({"arch": arch, "shape": shape, **m, **a})
                print(
                    f"{arch:26s} {shape:12s} comp={a['compute_s']*1e3:9.3f}ms "
                    f"mem={a['memory_s']*1e3:9.3f}ms coll={a['collective_s']*1e3:9.3f}ms "
                    f"dom={a['dominant']:10s} useful={a['useful_ratio']:6.2f}",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001
                rows.append({"arch": arch, "shape": shape, "error": f"{type(e).__name__}: {e}"})
                print(f"{arch:26s} {shape:12s} ERROR {e}", flush=True)
            with open(args.out, "w") as f:
                json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
