"""Per-step commit cost: sequential per-row commit (PR-1) vs the fused
device-resident commit, at 1/4/8 streams.

    PYTHONPATH=src python benchmarks/commit_bench.py [--streams 1,4,8]
        [--layers 4] [--smax 256] [--kv-heads 4] [--head-dim 64]
        [--tpad 8] [--iters 20] [--impl xla|pallas]

Builds a synthetic per-stream KV pool and a random accepted path per row
(the post-verification state of ``BatchedSpeculativeEngine.step``), then
commits it two ways:

  * sequential — ``serve_step.commit_row_reference`` per active row: each
    call's eager ``.at[].set`` chain materializes a fresh copy of the whole
    (L, B, Smax, Hkv, hd) pool, so device traffic is O(streams) pool copies;
  * fused      — ONE jitted ``serve_step.make_pool_commit_step`` call with
    the pool donated, so XLA moves only the touched (row, slot) lanes.

Reports wall-time per step (median over --iters, post-warmup) and the
analytic device-copy bytes each strategy moves per step.  The fused column
must win at 8 streams (ISSUE 2 acceptance criterion).  ``--json PATH``
writes the machine-readable ``BENCH_commit_bench.json`` document
(benchmarks/common.py ``write_bench_json``) the CI bench-smoke gate and the
checked-in baselines consume.
"""
from __future__ import annotations

import argparse
import statistics
import time
import types

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import write_bench_json
except ImportError:  # executed as a script: benchmarks/ itself is sys.path[0]
    from common import write_bench_json

from repro.serving.serve_step import commit_row_reference, make_pool_commit_step, next_pow2


def _pool(rng, L, B, S, H, hd):
    return {
        "attn": {
            "k": jnp.asarray(rng.normal(size=(L, B, S, H, hd)).astype(np.float32)),
            "v": jnp.asarray(rng.normal(size=(L, B, S, H, hd)).astype(np.float32)),
            "pos": jnp.asarray(rng.integers(-1, S, size=(B, S)).astype(np.int32)),
            "len": jnp.asarray(rng.integers(1, S // 2, size=(B,)).astype(np.int32)),
        }
    }


def _case(rng, n_active, B, S, Tpad):
    """One step's commit inputs: per-row C and a random accepted path."""
    paths, Cs = {}, {}
    for b in range(n_active):
        Cs[b] = int(rng.integers(1, S - Tpad))
        tau = int(rng.integers(0, Tpad))
        paths[b] = (
            sorted(rng.choice(np.arange(1, Tpad), size=tau, replace=False).tolist())
            if tau else []
        )
    return paths, Cs


def _bytes_sequential(L, B, S, H, hd, n_active, Tpad):
    """Each per-row commit rewrites full k and v (the eager copy) plus the
    row's pos table; len is negligible."""
    kv = 2 * L * B * S * H * hd * 4
    pos = B * S * 4
    return n_active * (kv + 2 * pos)


def _bytes_fused(L, B, S, H, hd, n_active, Tpad, P):
    """Donated fused commit: per active row, P KV lane moves per layer
    (read+write) plus the pos scatter rows."""
    lanes = 2 * L * P * H * hd * 4 * 2  # k and v, read + write
    pos = 2 * B * S * 4  # pos invalidate + rewrite over the donated table
    return n_active * lanes + pos


def run(args):
    sizes = [int(s) for s in args.streams.split(",")]
    B = max(sizes)
    L, S, H, hd, Tpad = args.layers, args.smax, args.kv_heads, args.head_dim, args.tpad
    cfg = types.SimpleNamespace(attention_impl=args.impl, kernel_interpret=True)
    rng = np.random.default_rng(args.seed)
    print(f"pool: L={L} B={B} Smax={S} Hkv={H} hd={hd}  Tpad={Tpad}  impl={args.impl}")
    print(f"{'streams':>8} {'seq ms/step':>12} {'fused ms/step':>14} {'speedup':>8} "
          f"{'seq MB/step':>12} {'fused MB/step':>14}")
    rows = []
    for n in sizes:
        paths, Cs = _case(rng, n, B, S, Tpad)
        P = next_pow2(max([len(p) for p in paths.values()] + [1]))
        npath = np.zeros((B, P), np.int32)
        plen = np.zeros((B,), np.int32)
        C = np.zeros((B,), np.int32)
        act = np.zeros((B,), np.bool_)
        for b in range(n):
            npath[b, : len(paths[b])] = paths[b]
            plen[b] = len(paths[b])
            C[b] = Cs[b]
            act[b] = True
        args_dev = tuple(jnp.asarray(a) for a in (npath, plen, C, act))
        fused_fn = jax.jit(make_pool_commit_step(cfg, Tpad), donate_argnums=0)

        def seq_step(pool):
            for b in range(n):
                pool = commit_row_reference(pool, b, Cs[b], paths[b], Tpad)
            return jax.block_until_ready(pool)

        def fused_step(pool):
            return jax.block_until_ready(fused_fn(pool, *args_dev))

        def bench(step):
            step(_pool(rng, L, B, S, H, hd))  # warm (compile)
            ts = []
            for _ in range(args.iters):
                pool = _pool(rng, L, B, S, H, hd)
                jax.block_until_ready(pool)
                t0 = time.perf_counter()
                step(pool)
                ts.append((time.perf_counter() - t0) * 1e3)
            return statistics.median(ts)

        seq_ms = bench(seq_step)
        fused_ms = bench(fused_step)
        sb = _bytes_sequential(L, B, S, H, hd, n, Tpad) / 1e6
        fb = _bytes_fused(L, B, S, H, hd, n, Tpad, P) / 1e6
        rows.append((n, seq_ms, fused_ms))
        print(f"{n:>8} {seq_ms:>12.3f} {fused_ms:>14.3f} {seq_ms / fused_ms:>7.2f}x "
              f"{sb:>12.2f} {fb:>14.3f}")
    if args.json:
        write_bench_json(
            args.json, "commit_bench",
            {"streams": sizes, "layers": L, "smax": S, "kv_heads": H,
             "head_dim": hd, "tpad": Tpad, "iters": args.iters,
             "impl": args.impl, "seed": args.seed},
            [{"streams": n, "commit_ms": {"sequential": s, "fused": f},
              "speedup_fused_vs_sequential": s / f} for n, s, f in rows],
        )
        print(f"wrote {args.json}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", default="1,4,8")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--smax", type=int, default=256)
    ap.add_argument("--kv-heads", type=int, default=4)
    ap.add_argument("--head-dim", type=int, default=64)
    ap.add_argument("--tpad", type=int, default=8)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--impl", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the BENCH_commit_bench.json document here")
    return run(ap.parse_args(argv))


if __name__ == "__main__":
    main()
