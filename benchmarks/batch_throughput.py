"""Continuous-batching throughput: batched pool engine vs. sequential loop,
with the pipelined stepping mode measured against both.

    PYTHONPATH=src python benchmarks/batch_throughput.py [--arch granite-8b]
        [--batch-sizes 1,4,8] [--max-new 24] [--verifier specinfer]
        [--ring] [--block-size 64] [--coresidency] [--heterogeneous]
        [--no-pipeline] [--no-ragged] [--data-shards 2]
        [--json BENCH_batch_throughput.json]

For each batch size N, serves N synthetic requests three ways:

  * sequential — one ``SpeculativeEngine``, requests one after another (the
    pre-batching serving path: throughput == single-stream latency);
  * batched    — ``BatchedSpeculativeEngine`` with an N-slot pool: every
    draft/target call advances all N streams;
  * pipelined  — the same engine with ``pipeline=True``: each step's host
    verify/retire tail overlaps the next step's dispatched device work
    (skipped with ``--no-pipeline``).

Reported tokens/sec is aggregate (all requests' emitted tokens / wall).
Wall-clock excludes compilation: each engine first runs the whole workload
untimed (populating its jit cache for every shape bucket the workload
hits), then the timed pass re-runs it — so the comparison prices the
steady-state serving loop.  The warmup pass doubles as the commit profiler
(it blocks on every fused commit for an honest ``commit_ms``) and as the
occupancy probe; the timed pass runs unblocked, so commit dispatches
overlap host work exactly as they do in production for BOTH stepping
modes.  The batched and pipelined timed reps are interleaved in
alternating order (``_interleaved_timed``) so machine drift cannot
masquerade as a stepping-mode difference.  Outputs are seeded
identically, so the batched and pipelined columns also re-check the
exactness contract while they measure.

``--json`` writes the machine-readable ``BENCH_batch_throughput.json``
document (benchmarks/common.py ``write_bench_json``) that
scripts/bench_smoke.sh gates CI on and benchmarks/baselines/ archives.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

try:
    from benchmarks.common import write_bench_json
except ImportError:  # executed as a script: benchmarks/ itself is sys.path[0]
    from common import write_bench_json

from repro.configs import get_smoke
from repro.launch.serve import make_draft_cfg
from repro.models.transformer import init_params
from repro.serving.batch_engine import (
    BatchedSpeculativeEngine,
    ShardedBatchedSpeculativeEngine,
)
from repro.serving.engine import EngineConfig, SamplingParams, SpeculativeEngine


def _prompts(n, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=6).tolist() for _ in range(n)]


def _best_timed(workload, reps):
    """Minimum wall-clock over ``reps`` repeats of a deterministic workload.
    The tiny smoke configs finish in fractions of a second, where scheduler
    noise swamps single-shot timings; the minimum is the standard low-noise
    estimator (cf. ``timeit``) because interruptions — GC, page faults,
    noisy CI neighbours — only ever ADD time to a deterministic run."""
    times, outs = [], None
    for _ in range(reps):
        t0 = time.time()
        outs = workload()
        times.append(time.time() - t0)
    return outs, min(times)


def _interleaved_timed(workloads, reps):
    """Time several workloads rep-by-rep in alternating order (the order
    flips every round).  Sequential per-mode timing lets slow machine drift
    (thermal throttling, noisy neighbours) land entirely on whichever mode
    runs last — exactly the bias that made the pipelined column look slower
    than batched.  Interleaving spreads drift across all modes and the
    per-mode minimum (see ``_best_timed``) discards what noise remains.
    Returns ``{name: (outs, best_secs)}``."""
    times = {name: [] for name in workloads}
    outs = {}
    for rnd in range(reps):
        order = list(workloads)
        if rnd % 2:
            order.reverse()
        for name in order:
            t0 = time.time()
            outs[name] = workloads[name]()
            times[name].append(time.time() - t0)
    return {name: (outs[name], min(times[name])) for name in workloads}


def run_sequential(cfg, tp, dcfg, dp, ecfg, sampling, prompts, max_new, seeds, reps=1):
    eng = SpeculativeEngine(cfg, tp, dcfg, dp, ecfg, sampling)

    def workload():
        outs = []
        for p, sd in zip(prompts, seeds):
            eng.rng = np.random.default_rng(sd)
            outs.append(eng.generate(list(p), max_new=max_new))
        return outs

    t0 = time.time()
    workload()  # warm every shape the workload compiles
    warm = {"warmup_secs": time.time() - t0,
            "compile_count": eng.jit_compile_count()}
    return (*_best_timed(workload, reps), warm)


_OVERLAP_KEYS = ("pipeline_ahead", "pipeline_stalls", "pipeline_iterations")
_WARM_KEYS = ("commit_calls", "commit_ms", "blocks_reclaimed", "blocks_peak") \
    + _OVERLAP_KEYS


def prepare_batched(cfg, tp, dcfg, dp, ecfg, sampling, prompts, max_new, seeds,
                    paged=True, block_size=64, pipeline=False, data_shards=1,
                    ragged=True, selector=None):
    """Build a batched (or sharded) engine, run the warmup/profiling pass and
    return ``(eng, workload, commit_stats, peak_occ)`` ready for timing.

    The warmup pass compiles every shape bucket, profiles commits honestly
    (``profile_commits`` blocks on each fused commit — doing that in the
    timed pass would serialize the very overlap the pipeline exists to
    create) and probes pool occupancy whenever the used-block peak advances.
    The workload repeats deterministically, so the warmup's commit cost and
    peak occupancy are the timed pass's too."""
    if data_shards > 1:
        eng = ShardedBatchedSpeculativeEngine(
            cfg, tp, dcfg, dp, ecfg, sampling, selector=selector,
            n_slots=len(prompts), data_shards=data_shards, paged=paged,
            block_size=block_size, pipeline=pipeline, ragged=ragged)
    else:
        eng = BatchedSpeculativeEngine(cfg, tp, dcfg, dp, ecfg, sampling,
                                       selector=selector, n_slots=len(prompts),
                                       paged=paged, block_size=block_size,
                                       pipeline=pipeline, ragged=ragged)
    engines = eng.shards if data_shards > 1 else [eng]

    def workload():
        # per-pass units: the reported overlap counters describe ONE
        # workload pass, like the commit/occupancy numbers they sit next to
        eng.reset_counters(_OVERLAP_KEYS)
        rids = [eng.submit(list(p), max_new=max_new, seed=sd) for p, sd in zip(prompts, seeds)]
        outs = eng.run()
        return [outs[r]["tokens"] for r in rids]

    eng.profile_commits = True
    t0 = time.time()
    for p, sd in zip(prompts, seeds):
        eng.submit(list(p), max_new=max_new, seed=sd)
    peak = {"blocks": -1, "occ": {}}
    while eng.queue or eng.streams:
        eng.step()
        occ = eng.pool_occupancy()
        if occ and occ["target"]["blocks_used"] >= peak["blocks"]:
            peak = {"blocks": occ["target"]["blocks_used"], "occ": occ}
    eng.finished.clear()
    # cold-start compile budget: the warmup pass IS the compile phase (the
    # timed pass recompiles nothing), so its wall and the jit-cache census
    # after it are the numbers the bench_smoke compile-hygiene gate tracks
    warm = {"warmup_secs": time.time() - t0,
            "compile_count": eng.jit_compile_count()}
    commit_stats = {k: eng.counters[k] for k in
                    ("commit_calls", "commit_ms", "blocks_peak", "blocks_reclaimed")}
    # the per-shard peaks tell the scheduler-balance story the aggregate hides
    commit_stats["shard_blocks_peak"] = (
        [e.counters["blocks_peak"] for e in engines] if data_shards > 1 else None)
    # From here the steady-state serving loop runs with commits dispatched
    # async; zero the warmup's tallies so the timed pass reports its own.
    eng.profile_commits = False
    eng.reset_counters(_WARM_KEYS)
    return eng, workload, commit_stats, peak["occ"], warm


def run_batched(cfg, tp, dcfg, dp, ecfg, sampling, prompts, max_new, seeds,
                paged=True, block_size=64, pipeline=False, reps=1, data_shards=1,
                ragged=True):
    eng, workload, commit_stats, occ, _ = prepare_batched(
        cfg, tp, dcfg, dp, ecfg, sampling, prompts, max_new, seeds,
        paged=paged, block_size=block_size, pipeline=pipeline,
        data_shards=data_shards, ragged=ragged)
    outs, dt = _best_timed(workload, reps)
    counters = dict(eng.counters)
    counters.update(commit_stats)  # report the honest (blocked) commit numbers
    return outs, dt, counters, occ


def run_coresidency(cfg, tp, dcfg, dp, ecfg, sampling, seed, block_size=16):
    """The paged pool's headline scenario: 1 long + 7 short streams share an
    arena strictly smaller than TWO per-stream rings — HBM in which the ring
    layout could hold at most the long stream alone."""
    smax = ecfg.max_cache
    # size the arena from the block size the engine will actually use
    bs = BatchedSpeculativeEngine.normalize_block_size(smax, block_size)
    pool_blocks = (2 * smax) // bs - 1  # < 2 rings of HBM
    eng = BatchedSpeculativeEngine(cfg, tp, dcfg, dp, ecfg, sampling, n_slots=8,
                                   paged=True, block_size=bs, pool_blocks=pool_blocks)
    rng = np.random.default_rng(seed)
    long_max = max(16, smax // 2 - 12)  # the long stream spans many blocks
    eng.submit(rng.integers(0, cfg.vocab, size=12).tolist(), max_new=long_max, seed=seed)
    for i in range(7):
        eng.submit(rng.integers(0, cfg.vocab, size=4).tolist(), max_new=4, seed=seed + 1 + i)
    peak_resident, peak_occ = 0, {}
    while eng.queue or eng.streams:
        eng.step()
        if len(eng.streams) >= peak_resident:
            peak_resident = len(eng.streams)
            occ = eng.pool_occupancy()
            if occ:
                peak_occ = occ["target"]
    ring_fit = (pool_blocks * eng.block_size) // smax
    print(f"\n[coresidency] arena={pool_blocks} blocks x {eng.block_size} tokens "
          f"(= {pool_blocks * eng.block_size} slots, ring layout fits {ring_fit} "
          f"stream{'s' if ring_fit != 1 else ''} of Smax={smax})")
    print(f"  co-resident streams (peak): {peak_resident}  "
          f"blocks used at peak: {peak_occ.get('blocks_used', '?')}/{pool_blocks}  "
          f"fragmentation: {peak_occ.get('fragmentation', 0.0):.2f}  "
          f"reclaimed: {eng.counters['blocks_reclaimed']}  "
          f"evicted: {eng.counters['evicted']}")
    assert peak_resident >= 8, "expected the paged pool to co-host all 8 streams"
    return peak_resident, ring_fit


def run_heterogeneous(cfg, tp, dcfg, dp, ecfg, sampling, seed, max_new=16,
                      block_size=64, reps=5, json_path=None):
    """The ragged layout's headline scenario: ONE stream on an aggressive
    NDE action co-resident with 7 thin trees.

    A selector keyed on stream CONTENT (the first committed token — stable
    across engines and shard assignments) gives stream 0 a (4, 2, 4) action
    (19-node trees) and everyone else (1, 1, 0) (2-node trees).  Under the
    padded layout the pool-wide power-of-two bucket follows the single
    aggressive stream, so every thin tree ships Tpad = 19 lanes; the ragged
    layout ships the flat node total instead.  Both layouts run the same
    prompts/seeds and must agree token-for-token (the exactness contract);
    timing is interleaved like the batched/pipelined comparison.  The
    ``pad_fraction`` gap and the throughput ratio here are what
    scripts/bench_smoke.sh gates (``BENCH_batch_throughput_hetero.json``)."""
    n = 8
    aggressive, thin = (4, 2, 8), (1, 1, 0)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(2, cfg.vocab, size=6).tolist() for _ in range(n)]
    for i, p in enumerate(prompts):
        p[0] = 1 if i == 0 else 0  # the selector's content key
    seeds = [seed + 100 + i for i in range(n)]

    def selector(stream, eng):
        return aggressive if stream["committed"][0] == 1 else thin

    def build(ragged):
        eng = BatchedSpeculativeEngine(cfg, tp, dcfg, dp, ecfg, sampling,
                                       selector=selector, n_slots=n, paged=True,
                                       block_size=block_size, ragged=ragged)

        def workload():
            rids = [eng.submit(list(p), max_new=max_new, seed=sd)
                    for p, sd in zip(prompts, seeds)]
            outs = eng.run()
            return [outs[r]["tokens"] for r in rids]

        workload()  # warm every shape bucket the selector mix hits
        eng.reset_counters(("pad_nodes_total", "tree_lanes_total"))
        return eng, workload

    eng_pad, wl_pad = build(False)
    eng_rag, wl_rag = build("always")
    timed = _interleaved_timed({"padded": wl_pad, "ragged": wl_rag}, reps)
    outs_pad, dt_pad = timed["padded"]
    outs_rag, dt_rag = timed["ragged"]
    exact = outs_pad == outs_rag
    tok = sum(len(o) for o in outs_pad)

    def pad_frac(eng):
        c = eng.counters
        return c["pad_nodes_total"] / max(c["tree_lanes_total"], 1)

    pf_pad, pf_rag = pad_frac(eng_pad), pad_frac(eng_rag)
    print(f"\n[heterogeneous] 1 stream @ {aggressive} + {n - 1} @ {thin}, "
          f"max_new={max_new}")
    print(f"  {'layout':>8} {'tok/s':>10} {'pad_fraction':>13} "
          f"{'pad_nodes':>10} {'tree_lanes':>11}")
    for name, dt, eng in (("padded", dt_pad, eng_pad), ("ragged", dt_rag, eng_rag)):
        c = eng.counters
        print(f"  {name:>8} {tok / dt:>10.2f} {pad_frac(eng):>13.3f} "
              f"{c['pad_nodes_total']:>10} {c['tree_lanes_total']:>11}")
    print(f"  exact={'yes' if exact else 'NO'}  "
          f"ragged/padded throughput: {dt_pad / dt_rag:.2f}x  "
          f"pad_fraction {pf_pad:.3f} -> {pf_rag:.3f}")
    assert exact, "ragged layout diverged from padded on the heterogeneous mix"
    row = {
        "scenario": "heterogeneous",
        "streams": n,
        "aggressive_action": list(aggressive),
        "thin_action": list(thin),
        "max_new": max_new,
        "tokens": tok,
        "exact": bool(exact),
        "tokens_per_sec": {"padded": tok / dt_pad, "ragged": tok / dt_rag},
        "throughput_ratio_ragged_vs_padded": dt_pad / dt_rag,
        "pad_fraction": {"padded": pf_pad, "ragged": pf_rag},
        "pad_nodes_total": {"padded": eng_pad.counters["pad_nodes_total"],
                            "ragged": eng_rag.counters["pad_nodes_total"]},
        "tree_lanes_total": {"padded": eng_pad.counters["tree_lanes_total"],
                             "ragged": eng_rag.counters["tree_lanes_total"]},
    }
    if json_path:
        write_bench_json(json_path, "batch_throughput_hetero",
                         {"arch": cfg.name, "verifier": ecfg.verifier,
                          "streams": n, "aggressive_action": list(aggressive),
                          "thin_action": list(thin), "max_new": max_new,
                          "block_size": block_size, "max_cache": ecfg.max_cache,
                          "seed": seed}, [row])
        print(f"wrote {json_path}")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--batch-sizes", default="1,4,8")
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--verifier", default="specinfer")
    ap.add_argument("--K", type=int, default=2)
    ap.add_argument("--L1", type=int, default=1)
    ap.add_argument("--L2", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ring", action="store_true",
                    help="benchmark the PR-1 per-stream ring pool instead of paged")
    ap.add_argument("--block-size", type=int, default=64)
    ap.add_argument("--data-shards", type=int, default=1,
                    help="run the batched/pipelined columns through the "
                         "sharded engine (N shard-local pools on the mesh "
                         "data axis); per-shard occupancy is reported and "
                         "the exactness column still pins outputs to the "
                         "sequential engine")
    ap.add_argument("--coresidency", action="store_true",
                    help="run the long+short co-residency scenario instead of "
                         "the throughput sweep")
    ap.add_argument("--heterogeneous", action="store_true",
                    help="run the adversarial padding-waste scenario (one "
                         "aggressive-action stream + 7 thin trees, padded vs "
                         "ragged layout) instead of the throughput sweep")
    ap.add_argument("--ragged", default=True, action=argparse.BooleanOptionalAction,
                    help="ragged node-major tree dispatch for the batched/"
                         "pipelined columns (auto: ragged whenever the flat "
                         "node buffer beats the padded lane count; "
                         "--no-ragged pins the padded layout)")
    ap.add_argument("--pipeline", default=True, action=argparse.BooleanOptionalAction,
                    help="also measure the pipelined stepping mode "
                         "(--no-pipeline skips that column)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the BENCH_batch_throughput.json document here")
    ap.add_argument("--reps", type=int, default=5,
                    help="timed repetitions per mode; the reported wall is "
                         "the per-mode minimum (smoke configs are sub-second, "
                         "where single-shot timings are scheduler noise and "
                         "interruptions only ever add time)")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch)
    dcfg = make_draft_cfg(cfg)
    tp = init_params(cfg, jax.random.PRNGKey(args.seed))
    dp = init_params(dcfg, jax.random.PRNGKey(args.seed + 1))
    ecfg = EngineConfig(verifier=args.verifier, K=args.K, L1=args.L1, L2=args.L2,
                        max_cache=256, seed=args.seed)
    sampling = SamplingParams()

    if args.coresidency:
        run_coresidency(cfg, tp, dcfg, dp, ecfg, sampling, args.seed,
                        block_size=min(args.block_size, 16))
        return []

    if args.heterogeneous:
        print(f"arch={args.arch}(smoke) verifier={args.verifier} "
              f"scenario=heterogeneous")
        run_heterogeneous(cfg, tp, dcfg, dp, ecfg, sampling, args.seed,
                          max_new=args.max_new, block_size=args.block_size,
                          reps=args.reps, json_path=args.json)
        return []

    sizes = [int(s) for s in args.batch_sizes.split(",")]
    pool = "ring" if args.ring else f"paged(block={args.block_size})"
    if args.data_shards > 1:
        pool += f" x {args.data_shards} shards"
    print(f"arch={args.arch}(smoke) verifier={args.verifier} "
          f"action=({args.K},{args.L1},{args.L2}) max_new={args.max_new} pool={pool}")
    header = f"{'batch':>5} {'seq tok/s':>10} {'batched tok/s':>14}"
    if args.pipeline:
        header += f" {'pipelined tok/s':>16} {'pipe/sync':>9}"
    print(header + f" {'exact':>6}")
    rows, json_rows = [], []
    for n in sizes:
        prompts = _prompts(n, cfg.vocab, args.seed)
        seeds = [args.seed + 100 + i for i in range(n)]
        outs_s, dt_s, warm_s = run_sequential(cfg, tp, dcfg, dp, ecfg, sampling,
                                              prompts, args.max_new, seeds, reps=args.reps)
        # build + warm both stepping modes first, then time them with reps
        # interleaved — the batched-vs-pipelined comparison is the headline
        # number, so it must not absorb machine drift as a mode difference
        eng_b, wl_b, counters, occ, warm_b = prepare_batched(
            cfg, tp, dcfg, dp, ecfg, sampling, prompts, args.max_new, seeds,
            paged=not args.ring, block_size=args.block_size,
            data_shards=args.data_shards, ragged=args.ragged)
        workloads = {"batched": wl_b}
        eng_p, warm_p = None, {}
        if args.pipeline:
            eng_p, wl_p, pcommit, _, warm_p = prepare_batched(
                cfg, tp, dcfg, dp, ecfg, sampling, prompts, args.max_new, seeds,
                paged=not args.ring, block_size=args.block_size, pipeline=True,
                data_shards=args.data_shards, ragged=args.ragged)
            workloads["pipelined"] = wl_p
        timed = _interleaved_timed(workloads, args.reps)
        outs_b, dt_b = timed["batched"]
        counters.update({k: eng_b.counters[k] for k in _OVERLAP_KEYS})
        # padding-waste accounting for the tree pass (warmup + timed passes
        # of the same deterministic workload, so the FRACTION is per-pass)
        pad_nodes = eng_b.counters["pad_nodes_total"]
        tree_lanes = eng_b.counters["tree_lanes_total"]
        pad_fraction = pad_nodes / max(tree_lanes, 1)
        shard_pad_fraction = (
            [sh.counters["pad_nodes_total"] / max(sh.counters["tree_lanes_total"], 1)
             for sh in eng_b.shards] if args.data_shards > 1 else None)
        # actual emitted tokens (an evicted request returns fewer than
        # max_new); the exactness checks below pin all modes to this count
        tok = sum(len(o) for o in outs_s)
        exact = all(a == b for a, b in zip(outs_s, outs_b))
        dt_p, pipe_exact, pcounters = None, True, {}
        if args.pipeline:
            outs_p, dt_p = timed["pipelined"]
            pcounters = dict(eng_p.counters)
            pcounters.update(pcommit)
            pipe_exact = all(a == b for a, b in zip(outs_s, outs_p))
        rows.append((n, tok / dt_s, tok / dt_b,
                     tok / dt_p if dt_p else None, exact and pipe_exact))
        cc = max(counters["commit_calls"], 1)
        pool_note = ""
        if occ:
            # blocks_peak and blocks_total both describe the TARGET arena
            # (the engine scopes the peak counter to it)
            t = occ["target"]
            pool_note = (f"   pool: {counters['blocks_peak']}/{t['blocks_total']} blocks peak"
                         f" (frag {t['fragmentation']:.2f}, "
                         f"reclaimed {counters['blocks_reclaimed']})")
        if counters.get("shard_blocks_peak"):
            pool_note += "   shard peaks: " + "/".join(
                str(p) for p in counters["shard_blocks_peak"])
        line = f"{n:>5} {tok / dt_s:>10.2f} {tok / dt_b:>14.2f}"
        if dt_p:
            line += f" {tok / dt_p:>16.2f} {dt_b / dt_p:>8.2f}x"
        line += (f" {'yes' if exact and pipe_exact else 'NO':>6}"
                 f"   pad: {pad_fraction:.2f}"
                 + ("(" + "/".join(f"{f:.2f}" for f in shard_pad_fraction) + ")"
                    if shard_pad_fraction else "")
                 + f"   commit: {counters['commit_calls']} calls, "
                 f"{counters['commit_ms']:.1f} ms ({counters['commit_ms'] / cc:.2f} ms/call)")
        if pcounters:
            line += (f"   overlap: {pcounters['pipeline_ahead']} ahead, "
                     f"{pcounters['pipeline_stalls']} stalls / "
                     f"{pcounters['pipeline_iterations']} iters")
        line += (f"   compiles: {warm_s['compile_count']}s/"
                 f"{warm_b['compile_count']}b"
                 + (f"/{warm_p['compile_count']}p" if warm_p else "")
                 + f" (warmup {warm_b['warmup_secs']:.1f}s)")
        print(line + pool_note)
        json_rows.append({
            "batch": n,
            "tokens": tok,
            "tokens_per_sec": {
                "sequential": tok / dt_s,
                "batched": tok / dt_b,
                "pipelined": tok / dt_p if dt_p else None,
            },
            "speedup_batched_vs_sequential": dt_s / dt_b,
            "speedup_pipelined_vs_batched": dt_b / dt_p if dt_p else None,
            "exact": bool(exact),
            "pipeline_exact": bool(pipe_exact),
            "commit_calls": counters["commit_calls"],
            "commit_ms": counters["commit_ms"],
            "blocks_peak": counters["blocks_peak"],
            "blocks_reclaimed": counters["blocks_reclaimed"],
            "shard_blocks_peak": counters.get("shard_blocks_peak"),
            "pad_nodes_total": pad_nodes,
            "tree_lanes_total": tree_lanes,
            "pad_fraction": pad_fraction,
            "shard_pad_fraction": shard_pad_fraction,
            "pipeline_ahead": pcounters.get("pipeline_ahead"),
            "pipeline_stalls": pcounters.get("pipeline_stalls"),
            "pipeline_iterations": pcounters.get("pipeline_iterations"),
            "compile_count": {
                "sequential": warm_s["compile_count"],
                "batched": warm_b["compile_count"],
                "pipelined": warm_p.get("compile_count"),
            },
            "warmup_secs": {
                "sequential": warm_s["warmup_secs"],
                "batched": warm_b["warmup_secs"],
                "pipelined": warm_p.get("warmup_secs"),
            },
        })
    if len(rows) > 1:
        first, last = rows[0], rows[-1]
        scale = last[2] / first[2]
        print(f"\nbatched tokens/sec scaling {first[0]}->{last[0]} streams: {scale:.2f}x "
              f"(sequential stays ~flat by construction)")
    if args.json:
        write_bench_json(args.json, "batch_throughput",
                         {"arch": args.arch, "verifier": args.verifier,
                          "K": args.K, "L1": args.L1, "L2": args.L2,
                          "max_new": args.max_new, "batch_sizes": sizes,
                          "pool": pool, "block_size": args.block_size,
                          "data_shards": args.data_shards, "ragged": args.ragged,
                          "max_cache": ecfg.max_cache, "seed": args.seed},
                         json_rows)
        print(f"wrote {args.json}")
    return rows


if __name__ == "__main__":
    main()
