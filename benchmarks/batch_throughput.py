"""Continuous-batching throughput: batched pool engine vs. sequential loop.

    PYTHONPATH=src python benchmarks/batch_throughput.py [--arch granite-8b]
        [--batch-sizes 1,4,8] [--max-new 24] [--verifier specinfer]

For each batch size N, serves N synthetic requests two ways:

  * sequential — one ``SpeculativeEngine``, requests one after another (the
    pre-batching serving path: throughput == single-stream latency);
  * batched    — ``BatchedSpeculativeEngine`` with an N-slot pool: every
    draft/target call advances all N streams.

Reported tokens/sec is aggregate (all requests' emitted tokens / wall).
Wall-clock excludes compilation: each engine first runs the whole workload
untimed (populating its jit cache for every shape bucket the workload
hits), then the timed pass re-runs it — so the comparison prices the
steady-state serving loop.  Outputs are seeded identically, so the batched
column also re-checks the exactness contract while it measures.  Each row
surfaces the engine's commit counters (one fused commit call per step —
see benchmarks/commit_bench.py for the commit-path microbenchmark).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.launch.serve import make_draft_cfg
from repro.models.transformer import init_params
from repro.serving.batch_engine import BatchedSpeculativeEngine
from repro.serving.engine import EngineConfig, SamplingParams, SpeculativeEngine


def _prompts(n, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=6).tolist() for _ in range(n)]


def run_sequential(cfg, tp, dcfg, dp, ecfg, sampling, prompts, max_new, seeds):
    eng = SpeculativeEngine(cfg, tp, dcfg, dp, ecfg, sampling)

    def workload():
        outs = []
        for p, sd in zip(prompts, seeds):
            eng.rng = np.random.default_rng(sd)
            outs.append(eng.generate(list(p), max_new=max_new))
        return outs

    workload()  # warm every shape the workload compiles
    t0 = time.time()
    outs = workload()
    return outs, time.time() - t0


def run_batched(cfg, tp, dcfg, dp, ecfg, sampling, prompts, max_new, seeds):
    eng = BatchedSpeculativeEngine(cfg, tp, dcfg, dp, ecfg, sampling, n_slots=len(prompts))
    eng.profile_commits = True  # honest commit_ms: block on the commit op

    def workload():
        rids = [eng.submit(list(p), max_new=max_new, seed=sd) for p, sd in zip(prompts, seeds)]
        outs = eng.run()
        return [outs[r]["tokens"] for r in rids]

    workload()  # warm every shape the workload compiles
    eng.counters["commit_calls"] = 0
    eng.counters["commit_ms"] = 0.0
    t0 = time.time()
    outs = workload()
    return outs, time.time() - t0, dict(eng.counters)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--batch-sizes", default="1,4,8")
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--verifier", default="specinfer")
    ap.add_argument("--K", type=int, default=2)
    ap.add_argument("--L1", type=int, default=1)
    ap.add_argument("--L2", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch)
    dcfg = make_draft_cfg(cfg)
    tp = init_params(cfg, jax.random.PRNGKey(args.seed))
    dp = init_params(dcfg, jax.random.PRNGKey(args.seed + 1))
    ecfg = EngineConfig(verifier=args.verifier, K=args.K, L1=args.L1, L2=args.L2,
                        max_cache=256, seed=args.seed)
    sampling = SamplingParams()

    sizes = [int(s) for s in args.batch_sizes.split(",")]
    print(f"arch={args.arch}(smoke) verifier={args.verifier} "
          f"action=({args.K},{args.L1},{args.L2}) max_new={args.max_new}")
    print(f"{'batch':>5} {'seq tok/s':>10} {'batched tok/s':>14} {'speedup':>8} {'exact':>6}")
    rows = []
    for n in sizes:
        prompts = _prompts(n, cfg.vocab, args.seed)
        seeds = [args.seed + 100 + i for i in range(n)]
        outs_s, dt_s = run_sequential(cfg, tp, dcfg, dp, ecfg, sampling,
                                      prompts, args.max_new, seeds)
        outs_b, dt_b, counters = run_batched(cfg, tp, dcfg, dp, ecfg, sampling,
                                             prompts, args.max_new, seeds)
        tok = n * args.max_new
        exact = all(a == b for a, b in zip(outs_s, outs_b))
        rows.append((n, tok / dt_s, tok / dt_b, exact))
        cc = max(counters["commit_calls"], 1)
        print(f"{n:>5} {tok / dt_s:>10.2f} {tok / dt_b:>14.2f} "
              f"{dt_s / dt_b:>7.2f}x {'yes' if exact else 'NO':>6}"
              f"   commit: {counters['commit_calls']} calls, "
              f"{counters['commit_ms']:.1f} ms ({counters['commit_ms'] / cc:.2f} ms/call)")
    if len(rows) > 1:
        first, last = rows[0], rows[-1]
        scale = last[2] / first[2]
        print(f"\nbatched tokens/sec scaling {first[0]}->{last[0]} streams: {scale:.2f}x "
              f"(sequential stays ~flat by construction)")
    return rows


if __name__ == "__main__":
    main()
