"""Continuous-batching throughput: batched pool engine vs. sequential loop.

    PYTHONPATH=src python benchmarks/batch_throughput.py [--arch granite-8b]
        [--batch-sizes 1,4,8] [--max-new 24] [--verifier specinfer]
        [--ring] [--block-size 64] [--coresidency]

For each batch size N, serves N synthetic requests two ways:

  * sequential — one ``SpeculativeEngine``, requests one after another (the
    pre-batching serving path: throughput == single-stream latency);
  * batched    — ``BatchedSpeculativeEngine`` with an N-slot pool: every
    draft/target call advances all N streams.

Reported tokens/sec is aggregate (all requests' emitted tokens / wall).
Wall-clock excludes compilation: each engine first runs the whole workload
untimed (populating its jit cache for every shape bucket the workload
hits), then the timed pass re-runs it — so the comparison prices the
steady-state serving loop.  Outputs are seeded identically, so the batched
column also re-checks the exactness contract while it measures.  Each row
surfaces the engine's commit counters (one fused commit call per step —
see benchmarks/commit_bench.py for the commit-path microbenchmark).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.launch.serve import make_draft_cfg
from repro.models.transformer import init_params
from repro.serving.batch_engine import BatchedSpeculativeEngine
from repro.serving.engine import EngineConfig, SamplingParams, SpeculativeEngine


def _prompts(n, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=6).tolist() for _ in range(n)]


def run_sequential(cfg, tp, dcfg, dp, ecfg, sampling, prompts, max_new, seeds):
    eng = SpeculativeEngine(cfg, tp, dcfg, dp, ecfg, sampling)

    def workload():
        outs = []
        for p, sd in zip(prompts, seeds):
            eng.rng = np.random.default_rng(sd)
            outs.append(eng.generate(list(p), max_new=max_new))
        return outs

    workload()  # warm every shape the workload compiles
    t0 = time.time()
    outs = workload()
    return outs, time.time() - t0


def run_batched(cfg, tp, dcfg, dp, ecfg, sampling, prompts, max_new, seeds,
                paged=True, block_size=64):
    eng = BatchedSpeculativeEngine(cfg, tp, dcfg, dp, ecfg, sampling, n_slots=len(prompts),
                                   paged=paged, block_size=block_size)
    eng.profile_commits = True  # honest commit_ms: block on the commit op

    def workload():
        rids = [eng.submit(list(p), max_new=max_new, seed=sd) for p, sd in zip(prompts, seeds)]
        outs = eng.run()
        return [outs[r]["tokens"] for r in rids]

    # warmup pass doubles as the occupancy probe: it steps manually and
    # samples pool_occupancy() whenever the used-block peak advances, so the
    # timed pass below stays free of host polling (the workload repeats
    # deterministically, so the warmup's peak occupancy is the timed one)
    for p, sd in zip(prompts, seeds):
        eng.submit(list(p), max_new=max_new, seed=sd)
    peak = {"blocks": -1, "occ": {}}
    while eng.queue or eng.streams:
        eng.step()
        occ = eng.pool_occupancy()
        if occ and occ["target"]["blocks_used"] >= peak["blocks"]:
            peak = {"blocks": occ["target"]["blocks_used"], "occ": occ}
    eng.finished.clear()
    for key in ("commit_calls", "commit_ms", "blocks_reclaimed", "blocks_peak"):
        eng.counters[key] = 0
    t0 = time.time()
    outs = workload()
    return outs, time.time() - t0, dict(eng.counters), peak["occ"]


def run_coresidency(cfg, tp, dcfg, dp, ecfg, sampling, seed, block_size=16):
    """The paged pool's headline scenario: 1 long + 7 short streams share an
    arena strictly smaller than TWO per-stream rings — HBM in which the ring
    layout could hold at most the long stream alone."""
    smax = ecfg.max_cache
    # size the arena from the block size the engine will actually use
    bs = BatchedSpeculativeEngine.normalize_block_size(smax, block_size)
    pool_blocks = (2 * smax) // bs - 1  # < 2 rings of HBM
    eng = BatchedSpeculativeEngine(cfg, tp, dcfg, dp, ecfg, sampling, n_slots=8,
                                   paged=True, block_size=bs, pool_blocks=pool_blocks)
    rng = np.random.default_rng(seed)
    long_max = max(16, smax // 2 - 12)  # the long stream spans many blocks
    eng.submit(rng.integers(0, cfg.vocab, size=12).tolist(), max_new=long_max, seed=seed)
    for i in range(7):
        eng.submit(rng.integers(0, cfg.vocab, size=4).tolist(), max_new=4, seed=seed + 1 + i)
    peak_resident, peak_occ = 0, {}
    while eng.queue or eng.streams:
        eng.step()
        if len(eng.streams) >= peak_resident:
            peak_resident = len(eng.streams)
            occ = eng.pool_occupancy()
            if occ:
                peak_occ = occ["target"]
    ring_fit = (pool_blocks * eng.block_size) // smax
    print(f"\n[coresidency] arena={pool_blocks} blocks x {eng.block_size} tokens "
          f"(= {pool_blocks * eng.block_size} slots, ring layout fits {ring_fit} "
          f"stream{'s' if ring_fit != 1 else ''} of Smax={smax})")
    print(f"  co-resident streams (peak): {peak_resident}  "
          f"blocks used at peak: {peak_occ.get('blocks_used', '?')}/{pool_blocks}  "
          f"fragmentation: {peak_occ.get('fragmentation', 0.0):.2f}  "
          f"reclaimed: {eng.counters['blocks_reclaimed']}  "
          f"evicted: {eng.counters['evicted']}")
    assert peak_resident >= 8, "expected the paged pool to co-host all 8 streams"
    return peak_resident, ring_fit


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--batch-sizes", default="1,4,8")
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--verifier", default="specinfer")
    ap.add_argument("--K", type=int, default=2)
    ap.add_argument("--L1", type=int, default=1)
    ap.add_argument("--L2", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ring", action="store_true",
                    help="benchmark the PR-1 per-stream ring pool instead of paged")
    ap.add_argument("--block-size", type=int, default=64)
    ap.add_argument("--coresidency", action="store_true",
                    help="run the long+short co-residency scenario instead of "
                         "the throughput sweep")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch)
    dcfg = make_draft_cfg(cfg)
    tp = init_params(cfg, jax.random.PRNGKey(args.seed))
    dp = init_params(dcfg, jax.random.PRNGKey(args.seed + 1))
    ecfg = EngineConfig(verifier=args.verifier, K=args.K, L1=args.L1, L2=args.L2,
                        max_cache=256, seed=args.seed)
    sampling = SamplingParams()

    if args.coresidency:
        run_coresidency(cfg, tp, dcfg, dp, ecfg, sampling, args.seed,
                        block_size=min(args.block_size, 16))
        return []

    sizes = [int(s) for s in args.batch_sizes.split(",")]
    print(f"arch={args.arch}(smoke) verifier={args.verifier} "
          f"action=({args.K},{args.L1},{args.L2}) max_new={args.max_new} "
          f"pool={'ring' if args.ring else f'paged(block={args.block_size})'}")
    print(f"{'batch':>5} {'seq tok/s':>10} {'batched tok/s':>14} {'speedup':>8} {'exact':>6}")
    rows = []
    for n in sizes:
        prompts = _prompts(n, cfg.vocab, args.seed)
        seeds = [args.seed + 100 + i for i in range(n)]
        outs_s, dt_s = run_sequential(cfg, tp, dcfg, dp, ecfg, sampling,
                                      prompts, args.max_new, seeds)
        outs_b, dt_b, counters, occ = run_batched(
            cfg, tp, dcfg, dp, ecfg, sampling, prompts, args.max_new, seeds,
            paged=not args.ring, block_size=args.block_size)
        tok = n * args.max_new
        exact = all(a == b for a, b in zip(outs_s, outs_b))
        rows.append((n, tok / dt_s, tok / dt_b, exact))
        cc = max(counters["commit_calls"], 1)
        pool = ""
        if occ:
            # blocks_peak and blocks_total both describe the TARGET arena
            # (the engine scopes the peak counter to it)
            t = occ["target"]
            pool = (f"   pool: {counters['blocks_peak']}/{t['blocks_total']} blocks peak"
                    f" (frag {t['fragmentation']:.2f}, reclaimed {counters['blocks_reclaimed']})")
        print(f"{n:>5} {tok / dt_s:>10.2f} {tok / dt_b:>14.2f} "
              f"{dt_s / dt_b:>7.2f}x {'yes' if exact else 'NO':>6}"
              f"   commit: {counters['commit_calls']} calls, "
              f"{counters['commit_ms']:.1f} ms ({counters['commit_ms'] / cc:.2f} ms/call)"
              f"{pool}")
    if len(rows) > 1:
        first, last = rows[0], rows[-1]
        scale = last[2] / first[2]
        print(f"\nbatched tokens/sec scaling {first[0]}->{last[0]} streams: {scale:.2f}x "
              f"(sequential stays ~flat by construction)")
    return rows


if __name__ == "__main__":
    main()
