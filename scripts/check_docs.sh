#!/usr/bin/env bash
# Docs lint: every code path README.md / docs/*.md cite must resolve to a
# real file, and the tier-1 command ROADMAP.md documents must match what
# scripts/tier1.sh actually runs.  Wired into scripts/tier1.sh so the docs
# cannot drift from the tree.
set -euo pipefail
cd "$(dirname "$0")/.."
python - <<'EOF'
import os
import re
import sys

fail = []

# --- 1. path references in the docs resolve -----------------------------
docs = ["README.md"] + sorted(
    os.path.join("docs", f) for f in os.listdir("docs") if f.endswith(".md")
)
# backtick-quoted tokens that look like repo paths: contain a slash or end
# in a known source suffix; trailing :line / #anchor / CLI tails stripped
token_re = re.compile(r"`([A-Za-z0-9_./-]+)`")
suffixes = (".py", ".sh", ".md", ".txt", ".toml", ".yml", ".json")
for doc in docs:
    text = open(doc, encoding="utf-8").read()
    for tok in token_re.findall(text):
        base = tok.split(":")[0].split("#")[0]
        if base.startswith(("http", "--")):
            continue
        candidates = [base, os.path.join("src", "repro", base)]
        if base.endswith(suffixes):
            pass  # file-suffixed tokens are always checked
        elif "/" in base and any(os.path.isdir(c) for c in candidates):
            continue  # directory-style tokens: existing dir is enough
        else:
            continue  # not a path-shaped token (CLI flags, ratios, ...)
        if not any(os.path.exists(c) for c in candidates):
            fail.append(f"{doc}: `{tok}` does not resolve "
                        f"(tried {', '.join(candidates)})")

# --- 2. ROADMAP's tier-1 command matches scripts/tier1.sh ---------------
roadmap = open("ROADMAP.md", encoding="utf-8").read()
tier1 = open("scripts/tier1.sh", encoding="utf-8").read()
m = re.search(r"\*\*Tier-1 verify:\*\*\s*`([^`]+)`", roadmap)
if not m:
    fail.append("ROADMAP.md: no `**Tier-1 verify:** `...`` line found")
else:
    cmd = m.group(1)
    core = re.search(r"python -m pytest\S*(?:\s+-\S+)*", cmd)
    if core is None:
        fail.append(f"ROADMAP.md: tier-1 command {cmd!r} is not a pytest invocation")
    elif "python -m pytest -x -q" not in cmd:
        fail.append(f"ROADMAP.md: tier-1 command {cmd!r} drifted")
    if "python -m pytest -x -q" not in tier1:
        fail.append("scripts/tier1.sh no longer runs the ROADMAP tier-1 core "
                    "command `python -m pytest -x -q`")

if fail:
    print("check_docs FAILED:")
    for f in fail:
        print("  -", f)
    sys.exit(1)
print(f"check_docs: {len(docs)} docs OK, tier-1 command in sync")
EOF
