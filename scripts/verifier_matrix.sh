#!/usr/bin/env bash
# Verifier-matrix CI gate (per-PR tier): run the quick Table-1 cross-verifier
# matrix (benchmarks/verifier_tables.py --matrix) over the WHOLE
# core/verify.py registry and FAIL if
#
#   * the harness crashes,
#   * any verifier's matrix coverage is missing — every registered name must
#     appear in every cell kind (a verifier added to the registry but
#     silently dropped from the matrix is exactly the drift this gate
#     exists to catch),
#   * any losslessness cell's enumeration gap reaches the gate (1e-9): the
#     verifier's composed block law no longer equals the target process,
#   * any engine exactness cell fails: batched+pipelined (and, in full mode,
#     sharded) serving must emit token-identical outputs to the sequential
#     engine for EVERY verifier on BOTH target-pass strategies,
#   * the emitted BENCH_verifier_matrix.json drifts structurally (schema
#     version / config keys / per-row keys per cell kind) from the committed
#     baseline benchmarks/baselines/BENCH_verifier_matrix.json.
#
# MATRIX_FULL=1 runs the full temperature x config grid (the weekly tier /
# run-slow label); the quick slice is the default on PRs.
#
#   BENCH_OUT=dir   where to write the JSON artifact (default bench_out/)
set -euo pipefail
cd "$(dirname "$0")/.."
OUT="${BENCH_OUT:-bench_out}"
mkdir -p "$OUT"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

FULL_FLAG=""
if [[ "${MATRIX_FULL:-0}" == "1" ]]; then
    FULL_FLAG="--full"
fi
python benchmarks/verifier_tables.py --matrix $FULL_FLAG \
    --json "$OUT/BENCH_verifier_matrix.json"

python - "$OUT" <<'EOF'
import json
import sys

sys.path.insert(0, "src")
from repro.core.verify import verifier_names

out = sys.argv[1]
with open(f"{out}/BENCH_verifier_matrix.json", encoding="utf-8") as f:
    doc = json.load(f)
assert doc["bench"] == "verifier_matrix" and doc["schema"] == 1, "unknown bench schema"

gate = doc["config"]["lossless_gate"]
cells = {"lossless", "block_efficiency", "exactness"}
seen = {c: set() for c in cells}
for r in doc["results"]:
    seen[r["cell"]].add(r["verifier"])
    if r["cell"] == "lossless":
        assert r["gap"] < gate, \
            f"{r['verifier']} ({r['K']},{r['L1']},{r['L2']}): losslessness " \
            f"gap {r['gap']:.3e} >= {gate} — the verifier's block law no " \
            f"longer matches the target process"
    elif r["cell"] == "exactness":
        assert r["exact"], \
            f"{r['verifier']} on {r['arch']} ({r['strategy']}): batched+" \
            f"pipelined output diverged from the sequential engine"
        assert r.get("sharded_exact", True), \
            f"{r['verifier']} on {r['arch']}: sharded output diverged " \
            f"from the sequential engine"

registered = set(verifier_names())
for cell in sorted(cells):
    missing = registered - seen[cell]
    assert not missing, \
        f"registered verifiers missing from the {cell} cells: " \
        f"{sorted(missing)} — the matrix no longer covers the registry"

# structural drift vs the committed baseline (same contract as bench_smoke)
with open("benchmarks/baselines/BENCH_verifier_matrix.json", encoding="utf-8") as f:
    base = json.load(f)
drift = []
if doc["schema"] != base["schema"]:
    drift.append(f"schema version {base['schema']} -> {doc['schema']}")
if set(doc["config"]) != set(base["config"]):
    drift.append(f"config keys: added {sorted(set(doc['config']) - set(base['config']))}, "
                 f"removed {sorted(set(base['config']) - set(doc['config']))}")
base_keys = {r["cell"]: set(r) for r in base["results"]}
for r in doc["results"]:
    extra = set(r) - base_keys[r["cell"]] - {"sharded_exact"}  # full-mode-only key
    missing = base_keys[r["cell"]] - set(r)
    if extra or missing:
        drift.append(f"{r['cell']} row keys: added {sorted(extra) or '-'}, "
                     f"removed {sorted(missing) or '-'}")
        break
assert not drift, \
    "BENCH_verifier_matrix.json drifted from its committed baseline " \
    "without regeneration:\n  " + "\n  ".join(drift)

n = {c: len(seen[c]) for c in sorted(cells)}
worst = max(r["gap"] for r in doc["results"] if r["cell"] == "lossless")
print(f"verifier matrix OK ({doc['config']['mode']}): "
      f"{len(registered)} verifiers x {n} cells; worst lossless gap {worst:.2e}; "
      f"all engine cells token-exact; no schema drift")
EOF
