#!/usr/bin/env bash
# Fast tier-1 verify in one invocation: docs lint, ruff (when installed),
# then the non-slow test tier with the src/ tree on PYTHONPATH (see
# ROADMAP.md "Tier-1 verify" for the full run).
#
#   scripts/tier1.sh            # fast tier
#   scripts/tier1.sh -k commit  # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
scripts/check_docs.sh
if command -v ruff >/dev/null 2>&1; then
    ruff check .
elif python -m ruff --version >/dev/null 2>&1; then
    python -m ruff check .
else
    echo "tier1: ruff not installed; skipping lint (CI runs it)"
fi
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q -m "not slow" "$@"
