#!/usr/bin/env bash
# Fast tier-1 verify in one invocation: the non-slow test tier with the
# src/ tree on PYTHONPATH (see ROADMAP.md "Tier-1 verify" for the full run).
#
#   scripts/tier1.sh            # fast tier
#   scripts/tier1.sh -k commit  # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
scripts/check_docs.sh
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q -m "not slow" "$@"
