#!/usr/bin/env bash
# Bench-regression smoke gate: run tiny-config variants of the serving
# benchmarks, write their machine-readable BENCH_<name>.json documents
# (benchmarks/common.py write_bench_json; committed baselines live in
# benchmarks/baselines/), and FAIL if
#
#   * either harness crashes,
#   * a batched/pipelined run is not token-exact against the sequential
#     engine,
#   * pipelined stepping does not BEAT the synchronous batched throughput
#     (strictly greater than BENCH_TOL x batched; BENCH_TOL defaults to
#     1.0 — the pipeline must earn its keep.  The bench prices this
#     fairly: timed reps are interleaved across the two modes and the
#     per-mode minimum is reported, so machine drift and scheduler noise
#     cannot masquerade as a stepping-mode difference),
#   * the fused commit stops beating the sequential per-row commit,
#   * the smoke workload's jit compile count grows past the committed
#     baseline (benchmarks/baselines/BENCH_batch_throughput*.json
#     ``compile_count``) for any engine mode — cold-start compile is the
#     real cost of rolling out a config at fleet scale, so jit-cache
#     growth is a tracked regression exactly like throughput.  Counts
#     are deterministic for a fixed workload; shrink is allowed (update
#     the baseline to lock it in),
#   * any emitted BENCH_*.json drifts structurally from its committed
#     baseline — schema version, config key set, or per-row result key
#     set — without the baseline being regenerated.  Added or removed
#     keys are listed; silent schema drift is how gates rot,
#   * the heterogeneous padding-waste scenario (one aggressive-action
#     stream + 7 thin trees — benchmarks/batch_throughput.py
#     --heterogeneous) loses ragged-vs-padded exactness, its ragged
#     pad_fraction stops DROPPING below the padded layout's, or ragged
#     throughput falls below BENCH_TOL x the padded layout — the ragged
#     dispatch must beat padding where padding is worst, or it has no
#     reason to exist,
#   * the --data-shards 2 host-local run loses exactness, its
#     commit_calls exceed the single-shard run's by more than one
#     dispatch per shard (the grouped cross-shard commit batches the
#     shards' staged index tables into ONE dispatch — losing that
#     regrouping silently doubled commit work once already), or its
#     batched throughput falls below BENCH_SHARD_TOL x the single-shard
#     batched throughput at 8 streams.  On ONE device the two shards
#     serialize — two half-batch engines pay double per-call dispatch
#     overhead at smoke scale — so the sharded throughput tolerance
#     defaults looser (0.85): that gate exists to catch collapse
#     (accidental recompiles, cross-shard serialization bugs), not to
#     claim single-device parity.  On multi-device hosts the shards
#     overlap and this gate is very conservative.
#
#   BENCH_OUT=dir        where to write the JSON artifacts (default bench_out/)
#   BENCH_TOL=f          pipelined-vs-sync threshold (default 1.0, strict >)
#   BENCH_SHARD_TOL=f    sharded-vs-single-shard tolerance (default 0.85)
set -euo pipefail
cd "$(dirname "$0")/.."
OUT="${BENCH_OUT:-bench_out}"
TOL="${BENCH_TOL:-1.0}"
SHARD_TOL="${BENCH_SHARD_TOL:-0.85}"
mkdir -p "$OUT"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python benchmarks/batch_throughput.py --arch granite-8b --batch-sizes 8 \
    --max-new 12 --reps 3 --json "$OUT/BENCH_batch_throughput.json"
python benchmarks/batch_throughput.py --arch granite-8b --batch-sizes 8 \
    --max-new 12 --reps 3 --data-shards 2 --no-pipeline \
    --json "$OUT/BENCH_batch_throughput_sharded.json"
python benchmarks/batch_throughput.py --arch granite-8b --heterogeneous \
    --max-new 12 --reps 3 --json "$OUT/BENCH_batch_throughput_hetero.json"
python benchmarks/commit_bench.py --streams 1,8 --iters 5 --layers 2 \
    --smax 128 --json "$OUT/BENCH_commit_bench.json"

python - "$OUT" "$TOL" "$SHARD_TOL" <<'EOF'
import json
import sys

out, tol, shard_tol = sys.argv[1], float(sys.argv[2]), float(sys.argv[3])

with open(f"{out}/BENCH_batch_throughput.json", encoding="utf-8") as f:
    bt = json.load(f)
assert bt["bench"] == "batch_throughput" and bt["schema"] == 1, "unknown bench schema"
for row in bt["results"]:
    n, tps = row["batch"], row["tokens_per_sec"]
    assert row["exact"], f"batch={n}: batched output diverged from sequential"
    assert row["pipeline_exact"], f"batch={n}: pipelined output diverged from sequential"
    assert tps["batched"] > tps["sequential"], \
        f"batch={n}: batching lost to the sequential loop ({tps})"
    assert tps["pipelined"] is not None and tps["pipelined"] > tol * tps["batched"], \
        f"batch={n}: pipelined {tps['pipelined']:.1f} tok/s does not beat " \
        f"{tol} x synchronous {tps['batched']:.1f} tok/s"

with open(f"{out}/BENCH_batch_throughput_sharded.json", encoding="utf-8") as f:
    sh = json.load(f)
assert sh["config"]["data_shards"] == 2, "sharded run did not shard"
ratios = []
shards = sh["config"]["data_shards"]
for row, base in zip(sh["results"], bt["results"]):
    n = row["batch"]
    assert row["exact"], f"data-shards batch={n}: sharded output diverged from sequential"
    # the grouped cross-shard commit batches colocated shards' staged index
    # tables into one dispatch: at most one straggler dispatch per shard
    # (a step where only that shard is active cannot group) may remain
    assert row["commit_calls"] <= base["commit_calls"] + shards, \
        f"batch={n}: sharded commit_calls {row['commit_calls']} > " \
        f"single-shard {base['commit_calls']} + {shards} shards — " \
        f"the grouped commit stopped regrouping"
    sharded, single = row["tokens_per_sec"]["batched"], base["tokens_per_sec"]["batched"]
    assert sharded >= shard_tol * single, \
        f"batch={n}: sharded {sharded:.1f} tok/s < {shard_tol} x single-shard {single:.1f} tok/s"
    ratios.append(sharded / single)

# --- padding-waste gate: ragged must beat padding where padding is worst ---
with open(f"{out}/BENCH_batch_throughput_hetero.json", encoding="utf-8") as f:
    het = json.load(f)
hr = het["results"][0]
assert hr["exact"], "heterogeneous: ragged output diverged from the padded layout"
pf = hr["pad_fraction"]
assert pf["ragged"] < pf["padded"], \
    f"heterogeneous: ragged pad_fraction {pf['ragged']:.3f} did not drop " \
    f"below padded {pf['padded']:.3f} — the ragged layout stopped shrinking " \
    f"padding waste"
htps = hr["tokens_per_sec"]
assert htps["ragged"] >= tol * htps["padded"], \
    f"heterogeneous: ragged {htps['ragged']:.1f} tok/s < {tol} x padded " \
    f"{htps['padded']:.1f} tok/s"

with open(f"{out}/BENCH_commit_bench.json", encoding="utf-8") as f:
    cb = json.load(f)
assert cb["bench"] == "commit_bench" and cb["schema"] == 1, "unknown bench schema"
worst = min(r["speedup_fused_vs_sequential"] for r in cb["results"])
assert worst > 1.0, f"fused commit no longer beats the per-row chain ({worst:.2f}x)"

# --- compile-hygiene gate: smoke compile counts vs the committed baseline ---
compiles = []
for fname, doc in (("BENCH_batch_throughput.json", bt),
                   ("BENCH_batch_throughput_sharded.json", sh)):
    with open(f"benchmarks/baselines/{fname}", encoding="utf-8") as f:
        base_doc = json.load(f)
    for row, base in zip(doc["results"], base_doc["results"]):
        for mode, n in row["compile_count"].items():
            b = base["compile_count"][mode]
            if n is None or b is None:
                assert n == b, \
                    f"{fname} batch={row['batch']}: {mode} compile count " \
                    f"appeared/disappeared vs baseline ({b} -> {n})"
                continue
            assert n <= b, \
                f"{fname} batch={row['batch']}: {mode} jit compile count grew " \
                f"{b} -> {n} — cold-start budget regression (if intended, " \
                f"regenerate benchmarks/baselines/{fname})"
            compiles.append(f"{mode}:{n}")

# --- schema-drift gate: emitted documents vs their committed baselines -----
import os

def key_drift(kind, new, old):
    added, removed = sorted(set(new) - set(old)), sorted(set(old) - set(new))
    if added or removed:
        return [f"{kind}: added {added or '-'}, removed {removed or '-'}"]
    return []

for fname in sorted(os.listdir(out)):
    base_path = f"benchmarks/baselines/{fname}"
    if not (fname.startswith("BENCH_") and os.path.exists(base_path)):
        continue
    with open(f"{out}/{fname}", encoding="utf-8") as f:
        new = json.load(f)
    with open(base_path, encoding="utf-8") as f:
        old = json.load(f)
    drift = []
    if new["schema"] != old["schema"]:
        drift.append(f"schema version {old['schema']} -> {new['schema']}")
    drift += key_drift("config keys", new["config"], old["config"])
    for i, (nr, orow) in enumerate(zip(new["results"], old["results"])):
        drift += key_drift(f"results[{i}] keys", nr, orow)
    assert not drift, \
        f"{fname} drifted from benchmarks/baselines/{fname} without the " \
        f"baseline being regenerated:\n  " + "\n  ".join(drift)

pipe = [f"{r['tokens_per_sec']['pipelined'] / r['tokens_per_sec']['batched']:.2f}x"
        for r in bt["results"]]
commits = [f"{r['commit_calls']}/{b['commit_calls']}"
           for r, b in zip(sh["results"], bt["results"])]
print(f"bench smoke OK: pipelined/sync {', '.join(pipe)}; sharded/single "
      f"{', '.join(f'{r:.2f}x' for r in ratios)}; "
      f"sharded/single commit_calls {', '.join(commits)}; "
      f"hetero pad_fraction {pf['padded']:.2f} -> {pf['ragged']:.2f} ragged "
      f"({hr['throughput_ratio_ragged_vs_padded']:.2f}x tok/s); "
      f"fused commit worst case {worst:.2f}x over per-row; "
      f"compile counts at baseline ({', '.join(compiles)}); no schema drift")
EOF
