import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract memory/cost/collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
        --out results/dryrun.json

This file (and ONLY this file) forces 512 host platform devices — smoke
tests and benches see the real single CPU device.
"""
import argparse
import json
import re
import sys
import time
from functools import partial

import jax
import numpy as np

from repro.configs import get_config, list_arches
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, input_specs
from repro.launch.sharding import (
    batch_shardings,
    cache_shardings,
    param_shardings,
    opt_shardings,
)
from repro.models.transformer import forward, init_params, make_train_step
from repro.training.optim import AdamW

SDS = jax.ShapeDtypeStruct


# --------------------------------------------------------- HLO collectives ---

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2, "f8e4m3fn": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in an HLO dump."""
    out = {k: 0 for k in ["all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute"]}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^[%\w.\-]+\s*=\s*(.*)$", s)
        if not m:
            continue
        rhs = m.group(1)
        cm = _COLL_RE.match(rhs.split("(")[0].strip().split()[-1] if False else "")
        # find op name: tokens like "bf16[2048,4096]{1,0} all-gather(...)"
        opm = _COLL_RE.search(rhs)
        if not opm:
            continue
        op = opm.group(1)
        # only count if it's the op being applied (not a fused substring)
        if f" {op}(" not in rhs and not rhs.startswith(op + "("):
            continue
        shape_part = rhs[: opm.start()]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shape_part):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op] += nbytes
    return out


# ------------------------------------------------------------ lowering fns ---


def build_step(cfg, kind: str):
    if kind == "train":
        opt = AdamW(lr=1e-4)
        ts = make_train_step(cfg, opt)
        return ts, opt
    if kind == "prefill":
        def prefill(params, cache, tokens, enc_embeds=None, embeds=None):
            logits, new_cache, _ = forward(
                params, cfg, tokens, mode="full", cache=cache,
                enc_embeds=enc_embeds, embeds=embeds,
            )
            return logits[:, -1], new_cache
        return prefill, None

    def serve_step(params, cache, tokens):
        logits, new_cache, _ = forward(params, cfg, tokens, mode="decode", cache=cache)
        return logits, new_cache
    return serve_step, None


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False, compile_: bool = True,
              cfg_override=None):
    """Lower (and compile) one (arch x shape x mesh).  Returns a result dict.

    cfg_override: replace the registered config (the roofline harness lowers
    unrolled reduced-depth variants through the exact same path)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg0 = cfg_override if cfg_override is not None else get_config(arch)
    kind, kw, cfg = input_specs(cfg0, shape_name)
    # fake cache length: decode against a full context
    step, opt = build_step(cfg, kind)

    params_shapes = jax.eval_shape(partial(init_params, cfg), jax.random.PRNGKey(0))
    p_sh = param_shardings(mesh, params_shapes, cfg, mode="serve" if kind == "decode" else "train")

    from repro.models import act_sharding
    act_axes = ("pod", "data") if multi_pod else ("data",)

    t0 = time.time()
    if kind == "train":
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        o_sh = opt_shardings(mesh, p_sh, opt_shapes)
        b_sh = batch_shardings(mesh, kw["batch"])
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh), out_shardings=(p_sh, o_sh, None))
        with mesh, act_sharding.activation_sharding(mesh, act_axes):
            lowered = jitted.lower(params_shapes, opt_shapes, kw["batch"])
    else:
        c_sh = cache_shardings(mesh, kw["cache"], batch_sharded=SHAPES[shape_name]["batch"] > 1)
        b = SHAPES[shape_name]["batch"]
        ax = ("pod", "data") if multi_pod else ("data",)
        dsize = int(np.prod([mesh.shape[a] for a in ax]))
        from jax.sharding import NamedSharding, PartitionSpec as P

        tok_sh = NamedSharding(mesh, P(ax if len(ax) > 1 else ax[0]) if b % dsize == 0 else P())
        in_sh = [p_sh, c_sh, tok_sh]
        args = [params_shapes, kw["cache"], kw["tokens"]]
        extra_names = []
        for extra in ("enc_embeds", "embeds"):
            if extra in kw:
                in_sh.append(tok_sh)
                args.append(kw[extra])
                extra_names.append(extra)
        jitted = jax.jit(step, in_shardings=tuple(in_sh))
        with mesh, act_sharding.activation_sharding(mesh, act_axes):
            lowered = jitted.lower(*args)
    t_lower = time.time() - t0

    res = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": kind,
        "lower_s": round(t_lower, 1),
    }
    if not compile_:
        return res

    t0 = time.time()
    compiled = lowered.compile()
    res["compile_s"] = round(time.time() - t0, 1)

    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    res["flops"] = float(ca.get("flops", 0.0))
    res["hbm_bytes"] = float(ca.get("bytes accessed", 0.0))
    try:
        ma = compiled.memory_analysis()
        res["argument_bytes"] = int(getattr(ma, "argument_size_in_bytes", 0))
        res["output_bytes"] = int(getattr(ma, "output_size_in_bytes", 0))
        res["temp_bytes"] = int(getattr(ma, "temp_size_in_bytes", 0))
        res["peak_bytes"] = res["argument_bytes"] + res["temp_bytes"]
    except Exception as e:  # pragma: no cover
        res["memory_analysis_error"] = str(e)
    hlo = compiled.as_text()
    res["collectives"] = collective_bytes(hlo)
    res["collective_bytes_total"] = int(sum(res["collectives"].values()))
    return res


# ------------------------------------------------------------------- main ----


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["no", "yes", "both"], default="no")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    arches = list_arches() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]

    results = []
    for arch in arches:
        for shape in shapes:
            for mp in pods:
                try:
                    r = lower_one(arch, shape, multi_pod=mp, compile_=not args.no_compile)
                    status = "OK"
                except Exception as e:  # noqa: BLE001
                    r = {"arch": arch, "shape": shape, "mesh": "2x16x16" if mp else "16x16",
                         "error": f"{type(e).__name__}: {e}"}
                    status = "FAIL"
                results.append(r)
                flops = r.get("flops")
                print(
                    f"[{status}] {arch:26s} {shape:12s} {r['mesh']:8s} "
                    f"lower={r.get('lower_s','-')}s compile={r.get('compile_s','-')}s "
                    f"flops={flops:.3e}" if flops else
                    f"[{status}] {arch:26s} {shape:12s} {r['mesh']:8s} {r.get('error','')[:120]}",
                    flush=True,
                )
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    bad = [r for r in results if "error" in r]
    print(f"\n{len(results) - len(bad)}/{len(results)} lowered+compiled OK")
    if bad:
        for r in bad:
            print("FAILED:", r["arch"], r["shape"], r["mesh"], r["error"][:200])
        sys.exit(1)


if __name__ == "__main__":
    main()
