"""Parameter / cache / batch sharding rules.

Scheme: 2D FSDP x tensor-parallel.
  * up-projections  (.., d_in, d_out): d_in -> data (FSDP), d_out -> model (TP)
  * down-projections (.., d_in, d_out): d_in -> model, d_out -> data
  * MoE experts (L, E, ..): E -> model (expert parallel), dense dim -> data
  * per-channel vectors (biases, A_log, conv): last dim -> model
  * embeddings (V, D): V -> model, D -> data  (falls back when V % model != 0)
  * norms and scalars: replicated
  * the pod axis never shards parameters (pure data parallel across pods)

Every *parameter* rule is divisibility-guarded: an axis that does not divide
is dropped (replicated) rather than erroring, so odd vocabularies (49155,
51865, 92553) lower cleanly — but each drop is logged once per param class,
so a mis-sized mesh cannot silently replicate half the model.  The KV-pool
stream axis (``pool_specs``/``pool_shardings``) is the exception: a stream
axis that does not divide the data axis is a hard error (pad ``n_slots`` up
with :func:`pad_slots` rather than replicating a pool shard).
"""
from __future__ import annotations

import logging
import re

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_log = logging.getLogger(__name__)
_logged_drops: set[tuple[str, str]] = set()

# (param-name regex, per-ndim spec templates). Leading layer/group axes are
# padded with None automatically: the template matches the TRAILING dims.
_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    (r"embed$", ("model", "data")),
    (r"lm_head$", ("data", "model")),
    (r"patch_proj$", ("data", "model")),
    (r"(wq|wk|wv)$", ("data", "model")),
    (r"wo$", ("model", "data")),
    (r"(bq|bk|bv)$", ("model",)),
    (r"router$", ("data", None)),
    (r"(w_gate|w_up)$", ("data", "model")),       # dense mlp (d, f)
    (r"w_down$", ("model", "data")),              # dense mlp (f, d)
    (r"w_in$", ("data", "model")),
    (r"w_out$", ("model", "data")),
    (r"(w_x|w_y)$", ("data", "model")),
    (r"(w_a|w_i)$", ("model", None, None)),  # block-diagonal (nb, bd, bd)
    (r"conv_w$", (None, "model")),
    (r"(conv_b|A_log|dt_bias|lam|norm_z|b_a|b_i)$", ("model",)),
    (r"^D$", ("model",)),
]
# MoE expert tensors (detected by ndim): (L, E, d, f) / (L, E, f, d)
_MOE_RULES = {
    "w_gate": ("model", "data", None),
    "w_up": ("model", "data", None),
    "w_down": ("model", None, "data"),
}


def _spec_for(path: str, shape: tuple, mesh, cfg=None) -> P:
    name = path.split("/")[-1]
    ndim = len(shape)
    tmpl = None
    if name in _MOE_RULES and ndim == 4:
        tmpl = _MOE_RULES[name]
    else:
        for pat, t in _RULES:
            if re.search(pat, name):
                tmpl = t
                break
    if tmpl is None:
        return P()
    tmpl = list(tmpl)
    if len(tmpl) > ndim:
        return P()
    # head-aware guard: never split *inside* an attention head — tensor
    # parallelism must tile whole (kv-)heads or XLA is forced to replicate
    # the (B, T, H, S) attention intermediates (§Perf cycle 1).
    if cfg is not None and "model" in mesh.axis_names and getattr(cfg, "n_heads", 0):
        msize = mesh.shape["model"]
        if re.search(r"(wk|wv|bk|bv)$", name) and cfg.n_kv_heads % msize != 0:
            tmpl = [None if a == "model" else a for a in tmpl]
        if re.search(r"(wq|bq|wo)$", name) and cfg.n_heads % msize != 0:
            tmpl = [None if a == "model" else a for a in tmpl]
    full = (None,) * (ndim - len(tmpl)) + tuple(tmpl)
    # divisibility guard: drop (replicate) the axis, but say so once per
    # param class — silent drops hid a half-replicated model more than once
    out = []
    for dim, ax in zip(shape, full):
        if ax is None or ax not in mesh.axis_names or dim % mesh.shape[ax] != 0:
            if ax is not None and ax in mesh.axis_names:
                key = (name, ax)
                if key not in _logged_drops:
                    _logged_drops.add(key)
                    _log.warning(
                        "sharding: param class %r drops axis %r (dim %d %% "
                        "%s=%d != 0) -> replicated on that dim",
                        name, ax, dim, ax, mesh.shape[ax],
                    )
            out.append(None)
        else:
            out.append(ax)
    return P(*out)


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_shardings(mesh, params_shapes, cfg=None, mode: str = "train"):
    """Pytree of NamedSharding matching a pytree of ShapeDtypeStructs.

    mode "train": 2D FSDP x TP (weights also sharded on the data axis; the
                  compiler all-gathers per layer — right when amortised over
                  optimizer state and long sequences).
    mode "serve": pure TP — weights sharded on "model" only and *replicated*
                  across data.  Decode reads the weights once per token; the
                  per-step FSDP all-gather would dominate the step (§Perf
                  cycle 3).
    """

    def assign(kp, leaf):
        spec = _spec_for(_path_str(kp), leaf.shape, mesh, cfg)
        if mode == "serve":
            spec = P(*(None if ax == "data" or (isinstance(ax, tuple) and "data" in ax) else ax
                       for ax in (tuple(spec) + (None,) * (len(leaf.shape) - len(spec)))))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(assign, params_shapes)


def opt_shardings(mesh, param_sh, opt_state_shapes):
    """AdamW state: mu/nu mirror params; step replicated."""
    from repro.training.optim import AdamWState

    return AdamWState(
        step=NamedSharding(mesh, P()),
        mu=jax.tree.map(lambda s: s, param_sh),
        nu=jax.tree.map(lambda s: s, param_sh),
    )


def batch_spec(mesh) -> P:
    return P(("pod", "data") if "pod" in mesh.axis_names else "data")


def batch_shardings(mesh, batch_shapes):
    """Shard the leading batch dim of every batch leaf (guarded)."""
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    total = int(np.prod([mesh.shape[a] for a in axes]))

    def assign(leaf):
        if leaf.shape and leaf.shape[0] % total == 0:
            return NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0]))
        return NamedSharding(mesh, P())

    return jax.tree.map(assign, batch_shapes)


def cache_shardings(mesh, cache_shapes, *, batch_sharded: bool):
    """Decode-cache shardings.

    Attention k/v (L, B, S, Hkv, hd): batch -> data when divisible; the slot
    axis S -> model (flash-decode split-S: softmax partials reduce over the
    model axis).  Recurrent states (L, B, H, P, N): batch -> data, heads ->
    model.  pos/len replicated.
    """
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dsize = int(np.prod([mesh.shape[a] for a in axes]))
    msize = mesh.shape["model"]
    daxis = axes if len(axes) > 1 else axes[0]

    def assign(kp, leaf):
        path = _path_str(kp)
        name = path.split("/")[-1]
        shp = leaf.shape
        if name in ("pos", "len"):
            return NamedSharding(mesh, P())
        if name in ("k", "v") or name in ("cross_k", "cross_v"):
            b_ok = batch_sharded and len(shp) >= 2 and shp[1] % dsize == 0
            s_ok = len(shp) >= 3 and shp[2] % msize == 0
            return NamedSharding(
                mesh,
                P(None, daxis if b_ok else None, "model" if s_ok else None, None, None),
            )
        if name in ("state",):  # (L, B, H, P, N)
            b_ok = batch_sharded and shp[1] % dsize == 0
            h_ok = len(shp) > 2 and shp[2] % msize == 0
            return NamedSharding(
                mesh, P(*((None, daxis if b_ok else None, "model" if h_ok else None) + (None,) * (len(shp) - 3)))
            )
        if name in ("conv",):  # (L, B, K-1, C)
            b_ok = batch_sharded and shp[1] % dsize == 0
            c_ok = shp[-1] % msize == 0
            return NamedSharding(
                mesh, P(*((None, daxis if b_ok else None) + (None,) * (len(shp) - 3) + ("model" if c_ok else None,)))
            )
        if name in ("rec_state", "rec_conv"):  # (G, g-1, B, ..., D)
            b_ok = batch_sharded and shp[2] % dsize == 0
            d_ok = shp[-1] % msize == 0
            mid = (None,) * (len(shp) - 4)
            return NamedSharding(
                mesh, P(*((None, None, daxis if b_ok else None) + mid + ("model" if d_ok else None,)))
            )
        if name in ("tail_state", "tail_conv"):  # (rem, B, ..., D)
            b_ok = batch_sharded and shp[1] % dsize == 0
            d_ok = shp[-1] % msize == 0
            mid = (None,) * (len(shp) - 3)
            return NamedSharding(
                mesh, P(*((None, daxis if b_ok else None) + mid + ("model" if d_ok else None,)))
            )
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(assign, cache_shapes)


# ------------------------------------------------------- KV-pool stream axis ---


def pad_slots(n_slots: int, data: int) -> int:
    """Round ``n_slots`` up to a multiple of the mesh data axis.

    The pool's stream axis must divide the data axis EXACTLY (see
    ``pool_specs``): a shard that cannot take a whole slice would have to be
    replicated, silently doubling pool HBM and breaking the shard-local
    free-list invariant — padding with idle rows is always cheaper."""
    assert n_slots >= 1 and data >= 1, (n_slots, data)
    return -(-n_slots // data) * data


def pool_specs(mesh_axes: dict, cache: dict) -> dict:
    """PartitionSpec pytree for a per-stream cache pool (models/cache.py).

    The stream axis maps to ``"data"`` for every array family that has one
    (attn k/v axis 1, pos/len/block_tbl axis 0, ssm/conv axis 1, hybrid
    rec_* axis 2, tail_* axis 1); everything else replicates.  Unlike the
    parameter rules, the stream axis is NOT divisibility-guard-dropped: a
    pool whose ``n_slots`` does not divide the data axis is a hard error —
    pad ``n_slots`` up with :func:`pad_slots` instead of replicating a pool
    shard.  Paged arenas ((L, NBLK+1, block, Hkv, hd)) have no stream axis
    (and an odd trash block), so they replicate here; the sharded engine
    (serving/batch_engine.py ShardedBatchedSpeculativeEngine) gives every
    shard a *private* arena + free list instead, which is what keeps block
    allocation host-local.
    """
    assert "data" in mesh_axes, "pool sharding needs a mesh with a 'data' axis"
    data = int(mesh_axes["data"])

    def stream_spec(arr, axis: int) -> P:
        dim = arr.shape[axis]
        assert dim % data == 0, (
            f"KV-pool stream axis of size {dim} does not divide the mesh data "
            f"axis ({data}): pad n_slots with launch.sharding.pad_slots() "
            f"instead of replicating a pool shard"
        )
        spec = [None] * len(arr.shape)
        spec[axis] = "data"
        return P(*spec)

    out: dict = {}
    for key, val in cache.items():
        if key == "attn":
            a: dict = {}
            a["pos"] = stream_spec(val["pos"], 0) if val["pos"].ndim == 2 else P()
            a["len"] = stream_spec(val["len"], 0) if val["len"].ndim == 1 else P()
            if "block_tbl" in val:  # paged arena: blocks have no stream axis
                a["k"], a["v"] = P(), P()
                a["block_tbl"] = stream_spec(val["block_tbl"], 0)
            else:
                a["k"] = stream_spec(val["k"], 1)
                a["v"] = stream_spec(val["v"], 1)
            out[key] = a
        elif key in ("rec_state", "rec_conv"):
            out[key] = stream_spec(val, 2)
        elif key in ("state", "conv", "tail_state", "tail_conv", "cross_k", "cross_v"):
            out[key] = stream_spec(val, 1)
        elif key == "len":
            out[key] = stream_spec(val, 0) if val.ndim == 1 else P()
        else:
            out[key] = P()
    return out


def pool_shardings(mesh, cache: dict):
    """NamedSharding pytree for a cache pool over ``mesh``'s data axis —
    what ``make_cache_pool(..., sharding=...)`` commits the pool arrays to.
    Works with concrete arrays or ShapeDtypeStructs."""
    specs = pool_specs(dict(mesh.shape), cache)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )
