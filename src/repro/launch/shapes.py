"""Assigned input shapes + ShapeDtypeStruct factories for the dry-run.

Four shapes (assignment):
    train_4k:     seq 4096,    global batch 256   -> train_step
    prefill_32k:  seq 32768,   global batch 32    -> prefill (fills the cache)
    decode_32k:   seq 32768,   global batch 128   -> serve_step (1 new token)
    long_500k:    seq 524288,  global batch 1     -> serve_step; sub-quadratic
                  context required: SSM/hybrid run natively (O(1) state);
                  full-attention archs run the sliding-window variant
                  (window 8192 ring cache) per DESIGN.md — no arch skips.

``input_specs(cfg, shape)`` returns (step_kind, shape-struct kwargs, adapted
cfg) where every tensor is a ShapeDtypeStruct (zero allocation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer import init_cache

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

SDS = jax.ShapeDtypeStruct


def _tree_sds(tree):
    return jax.tree.map(lambda x: SDS(x.shape, x.dtype), tree)


def adapt_config(cfg, shape_name: str):
    """Shape-driven config adaptation (long-context attention variant)."""
    spec = SHAPES[shape_name]
    if shape_name == "long_500k" and cfg.arch_type in ("dense", "moe", "vlm", "encdec"):
        cfg = cfg.replace(attention="sliding_window", window=8192)
    return cfg


def cache_smax(cfg, shape_name: str) -> int:
    spec = SHAPES[shape_name]
    if cfg.arch_type == "hybrid":
        return cfg.local_window
    if cfg.attention == "sliding_window":
        return cfg.window
    return spec["seq"]


def input_specs(cfg, shape_name: str):
    """Returns (kind, kwargs-of-ShapeDtypeStructs, adapted_cfg)."""
    spec = SHAPES[shape_name]
    cfg = adapt_config(cfg, shape_name)
    B, S = spec["batch"], spec["seq"]
    kind = spec["kind"]
    dt = cfg.jdtype
    if kind == "train":
        toks = S
        kw = {}
        if cfg.arch_type == "vlm":
            toks = S - cfg.n_patches
            kw["embeds"] = SDS((B, cfg.n_patches, cfg.d_model), dt)
        if cfg.arch_type == "encdec":
            kw["enc_embeds"] = SDS((B, cfg.enc_len, cfg.d_model), dt)
        batch = {
            "tokens": SDS((B, toks), jnp.int32),
            "labels": SDS((B, toks), jnp.int32),
            **kw,
        }
        return kind, {"batch": batch}, cfg
    if kind == "prefill":
        smax = cache_smax(cfg, shape_name)
        cache = _tree_sds(jax.eval_shape(lambda: init_cache(cfg, B, smax)))
        toks = S
        kw = {}
        if cfg.arch_type == "vlm":
            toks = S - cfg.n_patches
            kw["embeds"] = SDS((B, cfg.n_patches, cfg.d_model), dt)
        if cfg.arch_type == "encdec":
            kw["enc_embeds"] = SDS((B, cfg.enc_len, cfg.d_model), dt)
        return kind, {"cache": cache, "tokens": SDS((B, toks), jnp.int32), **kw}, cfg
    # decode
    smax = cache_smax(cfg, shape_name)
    cache = _tree_sds(jax.eval_shape(lambda: init_cache(cfg, B, smax)))
    return kind, {"cache": cache, "tokens": SDS((B, 1), jnp.int32)}, cfg
