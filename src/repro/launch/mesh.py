"""Production mesh definitions (TPU v5e pods).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Axes that carry the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
