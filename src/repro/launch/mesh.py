"""Production mesh definitions (TPU v5e pods) and serving data meshes.

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Axes that carry the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def make_data_mesh(n_shards: int, *, devices=None):
    """A 1-axis ``("data",)`` mesh over ``n_shards`` distinct devices.

    The strict SPMD form: a single pool whose stream axis carries a
    ``NamedSharding`` over this mesh is physically split across the
    devices.  Raises when the host does not have enough devices — use
    :func:`shard_meshes` for the host-local fallback that cycles devices.
    """
    devices = list(devices if devices is not None else jax.devices())
    if len(devices) < n_shards:
        raise ValueError(
            f"a {n_shards}-shard data mesh needs {n_shards} devices, "
            f"this host has {len(devices)}"
        )
    return jax.sharding.Mesh(np.asarray(devices[:n_shards]), ("data",))


def shard_meshes(n_shards: int, *, devices=None) -> list:
    """One single-device ``("data",)`` mesh per shard, cycling the local
    devices — the host-local stand-in for one mesh slice per host.

    Shard ``i``'s pool arrays are NamedSharding-committed to
    ``devices[i % ndev]``: on a multi-device host the shards' pool steps
    dispatch onto distinct devices and overlap, while on a single-device
    container every shard shares device 0 (the smoke/test path, where the
    sharded engine must stay token-identical to the unsharded one)."""
    assert n_shards >= 1, n_shards
    devices = list(devices if devices is not None else jax.devices())
    return [
        jax.sharding.Mesh(np.asarray([devices[i % len(devices)]]), ("data",))
        for i in range(n_shards)
    ]
