"""Speculative-decoding serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
        --verifier specinfer --K 2 --L1 2 --L2 2 --requests 4 --max-new 32

Builds a (reduced) target + a proportionally smaller draft of the same
family, serves a batch of synthetic requests through the speculative engine,
and reports block efficiency + the Eq. 11 modelled throughput.

``--streams N`` switches to the continuous-batching engine: an N-slot KV
pool with FIFO admission, so requests beyond N queue and are admitted as
slots free up — every model call advances all resident streams at once.
Batched serving steps pipelined by default (each step's host verify/retire
tail overlaps the next step's dispatched device work, token-identically);
``--no-pipeline`` restores strictly sequential steps.

``--data-shards N`` splits the pool's stream axis into N shard engines
(shard-local slots, block arenas, admission queues; pool arrays committed
to the mesh data axis) under a least-loaded scheduler — token-identical to
the unsharded pool for the same arrival order.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke
from repro.core.verify import verifier_names
from repro.models.transformer import init_params
from repro.serving.batch_engine import (
    BatchedSpeculativeEngine,
    ShardedBatchedSpeculativeEngine,
)
from repro.serving.engine import EngineConfig, SamplingParams, SpeculativeEngine


def make_draft_cfg(cfg):
    """A ~10x smaller draft of the same family (paper: ~9:1 .. 100:1)."""
    if cfg.arch_type == "ssm":
        return cfg.replace(name=cfg.name + "-draft", n_layers=max(cfg.n_layers // 4, 1),
                           d_model=max(cfg.d_model // 2, 64))
    if cfg.arch_type == "hybrid":
        nl = max((cfg.n_layers // cfg.hybrid_attn_every) // 2 * cfg.hybrid_attn_every, cfg.hybrid_attn_every)
        return cfg.replace(name=cfg.name + "-draft", n_layers=nl,
                           d_model=max(cfg.d_model // 2, 64),
                           lru_width=max(cfg.lru_d // 2, 64),
                           d_ff=max(cfg.d_ff // 2, 64))
    kw = dict(
        name=cfg.name + "-draft",
        n_layers=max(cfg.n_layers // 4, 1),
        d_model=max(cfg.d_model // 2, 64),
        d_ff=max(cfg.d_ff // 2, 64),
        n_heads=max(cfg.n_heads // 2, 1),
        n_kv_heads=max(cfg.n_kv_heads // 2, 1),
    )
    if cfg.head_dim:
        kw["head_dim"] = cfg.head_dim
    if cfg.arch_type == "moe":
        kw["n_experts"] = max(cfg.n_experts // 2, 2)
        kw["top_k"] = min(cfg.top_k, max(cfg.n_experts // 2, 2))
    if cfg.arch_type == "encdec":
        kw["n_enc_layers"] = max(cfg.n_enc_layers // 4, 1)
    return cfg.replace(**kw)


def build_parser() -> argparse.ArgumentParser:
    """The serving CLI surface, exposed for tests: every registry verifier
    must round-trip through ``--verifier`` (tests/test_verifiers.py)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--verifier", default="specinfer", choices=verifier_names(),
                    help="verification algorithm (core/verify.py registry; "
                         "single-path verifiers bv/naive_single need --K 1)")
    ap.add_argument("--K", type=int, default=2)
    ap.add_argument("--L1", type=int, default=2)
    ap.add_argument("--L2", type=int, default=2)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--streams", type=int, default=0,
                    help="continuous batching: serve through an N-slot cache pool "
                         "(0 = sequential single-stream engine)")
    ap.add_argument("--data-shards", type=int, default=1,
                    help="shard the pool's stream axis across the mesh data "
                         "axis: N shard engines with shard-local slots, block "
                         "arenas and admission queues under a least-loaded "
                         "scheduler (1 = the unsharded pool)")
    ap.add_argument("--block-size", type=int, default=64,
                    help="paged KV pool block size in tokens (rounded down to "
                         "the nearest power of two dividing max_cache)")
    ap.add_argument("--pool-blocks", type=int, default=0,
                    help="total arena blocks shared by all streams (0 = "
                         "ring-equivalent capacity, streams * max_cache/block)")
    ap.add_argument("--ring", action="store_true",
                    help="disable the paged KV pool and reserve a full "
                         "max_cache ring per stream (the PR-1 layout)")
    ap.add_argument("--pipeline", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="pipelined stepping: overlap each step's host "
                         "verify/retire tail with the next step's dispatched "
                         "device work (token-identical; --no-pipeline "
                         "restores strictly sequential steps)")
    ap.add_argument("--ragged", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="ragged node-major tree batching: dispatch the tree "
                         "pass as one flat node buffer with per-stream "
                         "offsets whenever that is smaller than the padded "
                         "(slots, Tpad) block (token-identical; --no-ragged "
                         "pins the padded row-major layout)")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    dcfg = make_draft_cfg(cfg)
    key = jax.random.PRNGKey(args.seed)
    tp = init_params(cfg, key)
    dp = init_params(dcfg, jax.random.PRNGKey(args.seed + 1))

    ecfg = EngineConfig(verifier=args.verifier, K=args.K, L1=args.L1, L2=args.L2,
                        max_cache=1024, seed=args.seed)
    sampling = SamplingParams(args.temperature, args.top_p)
    rng = np.random.default_rng(args.seed)

    if args.streams:
        if args.data_shards > 1:
            eng = ShardedBatchedSpeculativeEngine(
                cfg, tp, dcfg, dp, ecfg, sampling, n_slots=args.streams,
                data_shards=args.data_shards, paged=not args.ring,
                block_size=args.block_size,
                pool_blocks=args.pool_blocks or None, pipeline=args.pipeline,
                ragged=args.ragged)
        else:
            eng = BatchedSpeculativeEngine(cfg, tp, dcfg, dp, ecfg, sampling,
                                           n_slots=args.streams, paged=not args.ring,
                                           block_size=args.block_size,
                                           pool_blocks=args.pool_blocks or None,
                                           pipeline=args.pipeline,
                                           ragged=args.ragged)
        t0 = time.time()
        rids = [
            eng.submit(rng.integers(0, cfg.vocab, size=8).tolist(),
                       max_new=args.max_new, seed=args.seed + r)
            for r in range(args.requests)
        ]
        outs = eng.run()
        for r, rid in enumerate(rids):
            out = outs[rid]["tokens"]
            print(f"req{r}: {out[:16]}{'...' if len(out) > 16 else ''}")
        dt = time.time() - t0
        c = eng.counters
        be = c["accepted"] / max(c["blocks"], 1) + 1
        pool = "ring" if not eng.paged else (
            f"paged(block={eng.block_size}, arena={eng.pool_blocks} blocks, "
            f"peak={c['blocks_peak']} used, reclaimed={c['blocks_reclaimed']})"
        )
        stepping = (
            f"pipelined(ahead={c['pipeline_ahead']}, stalls={c['pipeline_stalls']}"
            f"/{c['pipeline_iterations']} iters)"
            if args.pipeline else "sync"
        )
        if args.data_shards > 1:
            per = [sh.counters["blocks_peak"] for sh in eng.shards]
            # grouped commits are engine-level dispatches (no single shard
            # owns them); surfacing them shows the cross-shard batching
            grouped = eng._counters["commit_calls"]
            stepping += (f" shards={args.data_shards}"
                         f"(x{eng.n_slots // args.data_shards} slots, "
                         f"peaks={per}, commits={c['commit_calls']} "
                         f"of which {grouped} grouped)")
        print(
            f"\n[batched x{args.streams}] verifier={args.verifier} "
            f"({args.K},{args.L1},{args.L2}) block_efficiency={be:.3f} "
            f"target_calls={c['target_calls']} draft_tokens={c['draft_tokens']} "
            f"evicted={c['evicted']} pool={pool} stepping={stepping} "
            f"wall={dt:.1f}s "
            f"tokens/s(cpu)={sum(len(o['tokens']) for o in outs.values()) / dt:.2f}"
        )
        return

    eng = SpeculativeEngine(cfg, tp, dcfg, dp, ecfg, sampling)
    t0 = time.time()
    kw = {}
    if cfg.arch_type == "encdec":
        import jax.numpy as jnp
        kw["enc_embeds"] = jnp.asarray(rng.standard_normal((1, cfg.enc_len, cfg.d_model)), cfg.jdtype)
    for r in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=8).tolist()
        out = eng.generate(prompt, max_new=args.max_new, **kw)
        print(f"req{r}: {out[:16]}{'...' if len(out) > 16 else ''}")
    dt = time.time() - t0
    c = eng.counters
    be = c["accepted"] / max(c["blocks"], 1) + 1
    print(
        f"\nverifier={args.verifier} ({args.K},{args.L1},{args.L2}) "
        f"block_efficiency={be:.3f} target_calls={c['target_calls']} "
        f"draft_tokens={c['draft_tokens']} wall={dt:.1f}s "
        f"tokens/s(cpu)={args.requests * args.max_new / dt:.2f}"
    )


if __name__ == "__main__":
    main()
