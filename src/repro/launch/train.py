"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
        --steps 50 --batch 8 --seq 256

--smoke trains the reduced config on the local device(s); full configs are
meant for real pods (the mesh/shardings are the same code path the dry-run
proves out).  Data: SyntheticLM (offline container) or --data <memmap.bin>.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, get_smoke
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import batch_shardings, opt_shardings, param_shardings
from repro.models.transformer import init_params, make_train_step
from repro.training.data import MemmapDataset, SyntheticLM
from repro.training.loop import train
from repro.training.optim import AdamW


def add_modality_stubs(cfg, batch_iter, batch):
    """Attach stub modality embeddings to each batch when the arch needs them."""
    if cfg.arch_type not in ("encdec", "vlm"):
        yield from batch_iter
        return
    rng = np.random.default_rng(0)
    for b in batch_iter:
        if cfg.arch_type == "encdec":
            b["enc_embeds"] = rng.standard_normal((batch, cfg.enc_len, cfg.d_model)).astype(np.float32)
        else:
            b["embeds"] = rng.standard_normal((batch, cfg.n_patches, cfg.d_model)).astype(np.float32)
        yield b


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data", default=None, help="packed-token memmap path")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--distributed", action="store_true", help="use the production mesh")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    src = (
        MemmapDataset(args.data, cfg.vocab)
        if args.data
        else SyntheticLM(cfg.vocab, seed=0)
    )
    it = add_modality_stubs(cfg, src.batches(args.batch, args.seq), args.batch)

    opt = AdamW(lr=args.lr, total_steps=args.steps, warmup_steps=max(args.steps // 20, 1))
    train_step = None
    if args.distributed:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        params_shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
        p_sh = param_shardings(mesh, params_shapes, cfg)
        o_sh = opt_shardings(mesh, p_sh, jax.eval_shape(opt.init, params_shapes))
        step = make_train_step(cfg, opt)
        first = next(it)
        b_sh = batch_shardings(mesh, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), first))
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh), out_shardings=(p_sh, o_sh, None))

        def chained():
            yield first
            yield from it

        it = chained()
        train_step = jitted

    params, losses = train(
        cfg, it, steps=args.steps, lr=args.lr, ckpt_path=args.ckpt, train_step=train_step, opt=opt
    )
    print(f"final loss: {losses[-1][1]:.4f}")


if __name__ == "__main__":
    main()
