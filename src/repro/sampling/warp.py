"""Logit/probability warping: temperature + nucleus (top-p) sampling.

All verification algorithms in this framework operate on *warped* target and
draft distributions: the paper evaluates temperatures {0.2..1.2} and nucleus
{0.9, 0.99}.  Losslessness is always w.r.t. the warped target distribution.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def warp_logits(logits: jax.Array, temperature: float = 1.0, top_p: float = 1.0) -> jax.Array:
    """Apply temperature then nucleus filtering to logits.  Returns probabilities.

    Works on any leading batch shape; the last axis is the vocabulary.
    temperature==0 is greedy (one-hot argmax).
    """
    if temperature == 0.0:
        return jax.nn.one_hot(jnp.argmax(logits, axis=-1), logits.shape[-1], dtype=jnp.float32)
    probs = jax.nn.softmax(logits.astype(jnp.float32) / temperature, axis=-1)
    return warp_probs(probs, top_p=top_p)


def warp_probs(probs: jax.Array, top_p: float = 1.0) -> jax.Array:
    """Nucleus-filter a probability vector (last axis), renormalising.

    Keeps the smallest prefix of the sorted distribution whose mass is
    >= top_p (the token that crosses the threshold is kept, matching HF
    semantics).
    """
    if top_p >= 1.0:
        return probs
    sort_idx = jnp.argsort(probs, axis=-1)[..., ::-1]
    sorted_p = jnp.take_along_axis(probs, sort_idx, axis=-1)
    csum = jnp.cumsum(sorted_p, axis=-1)
    # keep tokens whose *preceding* cumulative mass is < top_p
    keep_sorted = (csum - sorted_p) < top_p
    keep = jnp.zeros_like(keep_sorted)
    keep = jnp.put_along_axis(keep, sort_idx, keep_sorted, axis=-1, inplace=False)
    filtered = jnp.where(keep, probs, 0.0)
    return filtered / jnp.sum(filtered, axis=-1, keepdims=True)


def sample_categorical(key: jax.Array, probs: jax.Array) -> jax.Array:
    """Sample token indices from probability vectors (last axis = vocab)."""
    # Gumbel trick on log-probs; robust to zeros.
    logp = jnp.log(jnp.clip(probs, 1e-30, None))
    g = jax.random.gumbel(key, probs.shape, dtype=jnp.float32)
    g = jnp.where(probs > 0, g, -jnp.inf)
    return jnp.argmax(logp + g, axis=-1)
