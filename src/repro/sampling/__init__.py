from repro.sampling.warp import warp_logits, warp_probs, sample_categorical

__all__ = ["warp_logits", "warp_probs", "sample_categorical"]
