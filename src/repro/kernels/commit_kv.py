"""Batched ring-compaction KV commit — Pallas TPU kernel.

After a speculative tree pass, every stream's accepted path must be
compacted into contiguous ring slots: slot (C + n_j) % Smax moves to
(C + 1 + j) % Smax for the j-th accepted node n_j.  Doing this with eager
``.at[].set`` chains materializes a fresh copy of the whole
(L, B, Smax, Hkv, hd) pool per stream; this kernel instead touches only the
(layer, row, slot) lanes named by the index arrays:

  * ``src``/``dst`` are scalar-prefetched (SMEM) so the grid's block index
    maps can steer the HBM->VMEM pipeline directly at the named slots — the
    unit of data movement is one (Hkv * hd) lane, not the pool;
  * ``input_output_aliases`` pins the output to the input buffer, so slots
    outside the index arrays are never read or written (the XLA-level
    donation the serving step relies on);
  * the grid's minor axis walks the path positions j in order.  TPU grids
    execute sequentially, which makes the in-place copy exact under the
    hazard-free index contract (see ``serve_step.make_pool_commit_step``):
    accepted node indices are strictly increasing with n_j >= j + 1, so a
    source slot is never an EARLIER entry's destination (and destinations
    are pairwise distinct) — every entry reads its pre-commit value, and
    the sequential copy equals gather-then-scatter.

Padding convention: masked entries carry src == dst (an identity copy of a
slot no real entry writes), so ragged per-row path lengths need no masking
inside the kernel.

Layout: k, v (L, B, Smax, Hkv, hd); src, dst (B, P) int32.  The feature
lanes are reshaped to (Hkv * hd,); real deployments have hd = 128 so the
lane dim is MXU/VPU aligned.

Paged pools reuse this kernel unchanged: logical slots are translated
through the block table and the arena is committed as a single-row pool
(see docs/kernels.md "The paged scatter").
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _commit_kv_kernel(src_ref, dst_ref, k_in, v_in, k_out, v_out):
    del src_ref, dst_ref  # consumed by the index maps
    k_out[...] = k_in[...]
    v_out[...] = v_in[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def commit_kv(k, v, src, dst, *, interpret: bool = True):
    """k[l, b, dst[b, j]] <- k[l, b, src[b, j]] (and likewise v), in place.

    k, v: (L, B, Smax, Hkv, hd); src, dst: (B, P) int32.  Requires the
    hazard-free contract documented in the module docstring; entries with
    src == dst are no-ops (the padding convention).

    In-place-ness comes from ``input_output_aliases`` plus the caller's
    buffer donation (the serving commit step is jitted with
    ``donate_argnums=0`` over the whole pool); this wrapper itself does not
    donate, so eager callers keep their inputs valid.
    """
    L, B, S, H, hd = k.shape
    P = src.shape[1]
    F = H * hd
    kf = k.reshape(L, B, S, F)
    vf = v.reshape(L, B, S, F)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(L, B, P),
        in_specs=[
            pl.BlockSpec((1, 1, 1, F), lambda l, b, j, src, dst: (l, b, src[b, j], 0)),
            pl.BlockSpec((1, 1, 1, F), lambda l, b, j, src, dst: (l, b, src[b, j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, F), lambda l, b, j, src, dst: (l, b, dst[b, j], 0)),
            pl.BlockSpec((1, 1, 1, F), lambda l, b, j, src, dst: (l, b, dst[b, j], 0)),
        ],
    )
    ko, vo = pl.pallas_call(
        _commit_kv_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(kf.shape, kf.dtype),
            jax.ShapeDtypeStruct(vf.shape, vf.dtype),
        ],
        input_output_aliases={2: 0, 3: 1},
        interpret=interpret,
    )(src, dst, kf, vf)
    return ko.reshape(k.shape), vo.reshape(v.shape)
