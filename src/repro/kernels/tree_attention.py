"""Masked flash attention for the speculative tree pass — Pallas TPU kernel.

The target pass of multi-path speculative decoding attends T tree tokens
against (a) a long committed prefix and (b) the speculation block itself with
an arbitrary ancestor mask.  On GPU this is a gather + custom-mask Flash
kernel (DeFT-style); the TPU-native formulation here:

  * queries: the whole (padded) tree block lives in VMEM for the entire
    kernel — T is tiny (<= 128), so the online-softmax state (m, l, acc)
    stays in VMEM scratch with no HBM round-trips;
  * keys/values stream HBM -> VMEM in ``block_k`` chunks along the grid's
    sequential minor axis (TPU grids execute in order, so cross-block
    accumulation needs no atomics — the GPU split-k reduction disappears);
  * the boolean mask streams with the same blocking; MXU matmuls are
    (T, D) x (D, block_k) with D = head_dim = 128 — hardware-aligned.

Layouts: q (BH, T, D);  k, v (BH, S, D);  mask (BH, T, S).  The ops.py
wrapper folds batch x heads and broadcasts GQA groups.

``paged_tree_attention`` is the block-table variant for the paged KV pool:
same kernel body, with the K/V index maps chasing a scalar-prefetched block
table (docs/kernels.md "Block-table attention").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_tile_body(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, acc_ref, j, nk):
    """One K/V-block step of the online softmax; j is the sequential minor
    grid axis (0-based), nk its extent."""

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (T, D)
    k = k_ref[0].astype(jnp.float32)  # (Bk, D)
    v = v_ref[0].astype(jnp.float32)  # (Bk, D)
    mask = mask_ref[0]  # (T, Bk) bool

    d = q.shape[-1]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) / (d**0.5)  # (T, Bk)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # (T, 1)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # (T, Bk); rows that are fully masked give exp(NEG_INF - m)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _tree_attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, acc_ref):
    _attn_tile_body(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, acc_ref,
                    pl.program_id(1), pl.num_programs(1))


def _paged_tree_attn_kernel(tbl_ref, q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, acc_ref):
    del tbl_ref  # consumed by the K/V index maps
    _tree_attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, acc_ref)


def _ragged_tree_attn_kernel(owners_ref, tbl_ref, q_ref, k_ref, v_ref, mask_ref,
                             o_ref, m_ref, l_ref, acc_ref):
    del owners_ref, tbl_ref  # consumed by the K/V index maps
    _attn_tile_body(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, acc_ref,
                    pl.program_id(2), pl.num_programs(2))


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_tree_attention(q, k_arena, v_arena, tbl, mask, *, interpret: bool = False):
    """Block-table tree attention: KV streams straight from the paged arena.

    q (BH, T, D); k_arena, v_arena (NBLK, block, D) — the folded per-head
    arena; tbl (BH, max_blocks) int32 physical block ids (pre-clamped:
    unmapped logical blocks point at the trash block and must be masked
    False); mask (BH, T, S) bool over LOGICAL slots, S = max_blocks*block.
    Returns (BH, T, D).

    Identical online-softmax body as ``tree_attention``; the only change is
    the K/V BlockSpec index maps, which chase the scalar-prefetched block
    table instead of walking logical slots — the grid's minor axis j is the
    *logical* block index, so the mask (and any iota-derived validity)
    stays in logical coordinates while HBM reads hit exactly the mapped
    arena blocks.  Oracle: kernels/ref.py ``paged_gather_kv_ref`` composed
    with ``tree_attention_ref``."""
    BH, T, D = q.shape
    nblk, block = k_arena.shape[0], k_arena.shape[1]
    nb = tbl.shape[1]
    assert mask.shape == (BH, T, nb * block), (mask.shape, (BH, T, nb * block))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, nb),
        in_specs=[
            pl.BlockSpec((1, T, D), lambda i, j, tbl: (i, 0, 0)),
            pl.BlockSpec((1, block, D), lambda i, j, tbl: (tbl[i, j], 0, 0)),
            pl.BlockSpec((1, block, D), lambda i, j, tbl: (tbl[i, j], 0, 0)),
            pl.BlockSpec((1, T, block), lambda i, j, tbl: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, T, D), lambda i, j, tbl: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T, 1), jnp.float32),
            pltpu.VMEM((T, 1), jnp.float32),
            pltpu.VMEM((T, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        _paged_tree_attn_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, T, D), q.dtype),
        interpret=interpret,
    )(tbl, q, k_arena, v_arena, mask)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ragged_paged_tree_attention(q, k_arena, v_arena, tbl, owners, mask, *,
                                interpret: bool = False):
    """Ragged node-major tree attention over a paged arena.

    The Q axis is not a per-stream tree block but the FLAT ragged node
    buffer of every active stream's tree concatenated (docs/serving.md),
    tiled in 8-row Q tiles of UNIFORM owner (the engine 8-aligns segment
    offsets under the pallas impl, so no tile straddles two streams):

      q (H, Np, D) — head-major flat nodes, Np a multiple of 8;
      k_arena, v_arena (Hkv*NBLK, block, D) — the head-folded arena
        (ops._fold_paged_arena output);
      tbl (B*H, max_blocks) — the folded per-(row, head) block table;
      owners (Np//8,) int32 — pool row of each Q tile;
      mask (Np//8, 8, S) bool over the owner row's LOGICAL slots.

    The grid is (H, n_tiles, nb): a second scalar-prefetch operand
    (``owners``) steers the K/V index maps — tile t of head h reads the
    arena blocks of tbl[owners[t]*H + h, j], so each node attends over its
    OWN stream's block table while sharing one kernel launch with every
    co-resident tree.  Same online-softmax body as ``tree_attention``.
    Oracle: kernels/ref.py ``ragged_tree_attention_ref``."""
    H, Np, D = q.shape
    block = k_arena.shape[1]
    nb = tbl.shape[1]
    n_tiles = Np // 8
    assert Np % 8 == 0, Np
    assert mask.shape == (n_tiles, 8, nb * block), (mask.shape, (n_tiles, 8, nb * block))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(H, n_tiles, nb),
        in_specs=[
            pl.BlockSpec((1, 8, D), lambda h, t, j, owners, tbl: (h, t, 0)),
            pl.BlockSpec((1, block, D),
                         lambda h, t, j, owners, tbl: (tbl[owners[t] * H + h, j], 0, 0)),
            pl.BlockSpec((1, block, D),
                         lambda h, t, j, owners, tbl: (tbl[owners[t] * H + h, j], 0, 0)),
            pl.BlockSpec((1, 8, block), lambda h, t, j, owners, tbl: (t, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, 8, D), lambda h, t, j, owners, tbl: (h, t, 0)),
        scratch_shapes=[
            pltpu.VMEM((8, 1), jnp.float32),
            pltpu.VMEM((8, 1), jnp.float32),
            pltpu.VMEM((8, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        _ragged_tree_attn_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((H, Np, D), q.dtype),
        interpret=interpret,
    )(owners, tbl, q, k_arena, v_arena, mask)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def tree_attention(q, k, v, mask, *, block_k: int = 512, interpret: bool = False):
    """q (BH, T, D); k, v (BH, S, D); mask (BH, T, S) -> (BH, T, D).

    S must be a multiple of block_k (caller pads; padded slots masked False).
    T should be a multiple of 8 and D of 128 for TPU tiling.
    """
    BH, T, D = q.shape
    S = k.shape[1]
    assert S % block_k == 0, (S, block_k)
    nk = S // block_k
    grid = (BH, nk)
    return pl.pallas_call(
        _tree_attn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T, D), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, T, block_k), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, T, D), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((T, 1), jnp.float32),
            pltpu.VMEM((T, 1), jnp.float32),
            pltpu.VMEM((T, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask)
