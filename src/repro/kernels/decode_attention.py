"""Flash-decode — Pallas TPU kernel for single-token decode attention.

The memory-bound core of decode_32k / long_500k: one query row per (batch,
head) against a KV cache of S slots.  No mask tensor: validity is computed
in-register from a streamed iota against the scalar cache length (and an
optional sliding window), so HBM traffic is exactly the KV bytes — the
roofline floor for decode.

TPU adaptation of GPU flash-decode: the split-K + cross-SM reduction becomes
a sequential grid walk over KV blocks with VMEM-resident (m, l, acc); the
8-sublane minimum tile means the single query row is padded to 8 rows (the
wrapper slices row 0 back out).

Layouts: q (BH, 8, D);  k, v (BH, S, D);  lengths (BH, 1) int32 in SMEM.

``paged_decode_attention`` is the block-table variant for the paged KV
pool: same kernel body, with the K/V index maps chasing a scalar-prefetched
block table (docs/kernels.md "Block-table attention").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, block_k, window):
    j = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[pl.program_id(0)]
    q = q_ref[0].astype(jnp.float32)  # (8, D)
    k = k_ref[0].astype(jnp.float32)  # (Bk, D)
    v = v_ref[0].astype(jnp.float32)

    d = q.shape[-1]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) / (d**0.5)  # (8, Bk)
    slot = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = slot < length
    if window:
        valid = valid & (slot >= length - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _paged_decode_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                         *, block, window):
    del tbl_ref  # consumed by the K/V index maps
    _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   block_k=block, window=window)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, k_arena, v_arena, tbl, lengths, *, window: int = 0,
                           interpret: bool = False):
    """Flash-decode over a paged KV pool: KV streams through the block table.

    q (BH, 8, D); k_arena, v_arena (NBLK, block, D); tbl (BH, max_blocks)
    int32 physical block ids (pre-clamped — unmapped logical blocks point at
    the trash block, which in-register validity already excludes because a
    stream's mapped blocks always cover slots [0, len)); lengths (BH,)
    int32.  Returns (BH, 8, D).

    Same kernel body as ``decode_attention``: the minor grid axis j is the
    logical block index, so the streamed iota validity (slot = j*block +
    lane < length, optionally windowed) is untouched; only the K/V index
    maps chase the scalar-prefetched table.  Oracle: kernels/ref.py
    ``paged_gather_kv_ref`` composed with ``decode_attention_ref``."""
    BH, R, D = q.shape
    nblk, block = k_arena.shape[0], k_arena.shape[1]
    nb = tbl.shape[1]
    kernel = functools.partial(_paged_decode_kernel, block=block, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(BH, nb),
        in_specs=[
            pl.BlockSpec((1, R, D), lambda i, j, tbl, lens: (i, 0, 0)),
            pl.BlockSpec((1, block, D), lambda i, j, tbl, lens: (tbl[i, j], 0, 0)),
            pl.BlockSpec((1, block, D), lambda i, j, tbl, lens: (tbl[i, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, R, D), lambda i, j, tbl, lens: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, R, D), q.dtype),
        interpret=interpret,
    )(tbl, lengths.reshape(BH), q, k_arena, v_arena)


@functools.partial(jax.jit, static_argnames=("block_k", "window", "interpret"))
def decode_attention(q, k, v, lengths, *, block_k: int = 1024, window: int = 0, interpret: bool = False):
    """q (BH, 8, D) (query broadcast over 8 sublanes, row 0 real);
    k, v (BH, S, D); lengths (BH, 1) int32.  Returns (BH, 8, D)."""
    BH, R, D = q.shape
    S = k.shape[1]
    assert S % block_k == 0, (S, block_k)
    grid = (BH, S // block_k)
    kernel = functools.partial(_decode_kernel, block_k=block_k, window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, R, D), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, R, D), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, R, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.reshape(BH), q, k, v)
