"""Jit'd public wrappers around the Pallas kernels.

Handle GQA head-group broadcasting, padding to TPU tile boundaries, and the
interpret-mode fallback (this container is CPU-only: interpret=True executes
the kernel body in Python for correctness validation; on TPU the same call
compiles to Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention import decode_attention
from repro.kernels.tree_attention import tree_attention


def pool_commit_kv(k, v, src, dst, *, use_pallas: bool = False, interpret: bool = True):
    """Ring-compaction commit over the per-stream KV pool.

    k, v (L, B, Smax, Hkv, hd); src, dst (B, P) int32 slot indices (padding
    entries carry src == dst).  The Pallas path (kernels/commit_kv.py) moves
    only the touched (layer, row, slot) lanes in place; the ref path is the
    pure-jnp gather/scatter oracle.  Both honour the hazard-free index
    contract documented in serve_step.make_pool_commit_step.
    """
    if use_pallas:
        from repro.kernels.commit_kv import commit_kv

        return commit_kv(k, v, src, dst, interpret=interpret)
    from repro.kernels.ref import commit_kv_ref

    return commit_kv_ref(k, v, src, dst)


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def gqa_tree_attention(q, k, v, mask, *, block_k: int = 512, interpret: bool = True):
    """Engine-layout tree attention.

    q (B, T, H, D); k, v (B, S, Hkv, D); mask (B, T, S) or (1, T, S) bool.
    Returns (B, T, H, D).
    """
    B, T, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    Tp = int(np.ceil(T / 8) * 8)
    bk = min(block_k, int(np.ceil(S / 128) * 128))
    qf = _pad_to(q.transpose(0, 2, 1, 3), 8, axis=2)  # (B, H, Tp, D)
    qf = qf.reshape(B * H, Tp, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, S, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, S, D)
    mb = jnp.broadcast_to(mask, (B, T, S))
    mb = _pad_to(mb, 8, axis=1)
    mb = jnp.broadcast_to(mb[:, None], (B, H, Tp, S)).reshape(B * H, Tp, S)
    # pad S to the block size (padded slots masked out)
    kf = _pad_to(kf, bk, axis=1)
    vf = _pad_to(vf, bk, axis=1)
    mb = _pad_to(mb, bk, axis=2)
    out = tree_attention(qf, kf, vf, mb, block_k=bk, interpret=interpret)
    return out.reshape(B, H, Tp, D)[:, :, :T].transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("block_k", "window", "interpret"))
def gqa_decode_attention(q, k, v, lengths, *, block_k: int = 1024, window: int = 0, interpret: bool = True):
    """Engine-layout flash-decode.

    q (B, 1, H, D); k, v (B, S, Hkv, D); lengths (B,) int32.
    Returns (B, 1, H, D).
    """
    B, _, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    bk = min(block_k, int(np.ceil(S / 128) * 128))
    qf = jnp.broadcast_to(q.transpose(0, 2, 1, 3), (B, H, 8, D)).reshape(B * H, 8, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, S, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, S, D)
    kf = _pad_to(kf, bk, axis=1)
    vf = _pad_to(vf, bk, axis=1)
    lf = jnp.broadcast_to(lengths[:, None], (B, H)).reshape(B * H, 1)
    out = decode_attention(qf, kf, vf, lf, block_k=bk, window=window, interpret=interpret)
    return out.reshape(B, H, 8, D)[:, :, :1].transpose(0, 2, 1, 3)
