"""Jit'd public wrappers around the Pallas kernels.

Handle GQA head-group broadcasting, padding to TPU tile boundaries, and the
interpret-mode fallback (this container is CPU-only: interpret=True executes
the kernel body in Python for correctness validation; on TPU the same call
compiles to Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention import decode_attention, paged_decode_attention
from repro.kernels.tree_attention import (
    paged_tree_attention,
    ragged_paged_tree_attention,
    tree_attention,
)


def pool_commit_kv(k, v, src, dst, *, use_pallas: bool = False, interpret: bool = True):
    """Ring-compaction commit over the per-stream KV pool.

    k, v (L, B, Smax, Hkv, hd); src, dst (B, P) int32 slot indices (padding
    entries carry src == dst).  The Pallas path (kernels/commit_kv.py) moves
    only the touched (layer, row, slot) lanes in place; the ref path is the
    pure-jnp gather/scatter oracle.  Both honour the hazard-free index
    contract documented in serve_step.make_pool_commit_step.
    """
    if use_pallas:
        from repro.kernels.commit_kv import commit_kv

        return commit_kv(k, v, src, dst, interpret=interpret)
    from repro.kernels.ref import commit_kv_ref

    return commit_kv_ref(k, v, src, dst)


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def gqa_tree_attention(q, k, v, mask, *, block_k: int = 512, interpret: bool = True):
    """Engine-layout tree attention.

    q (B, T, H, D); k, v (B, S, Hkv, D); mask (B, T, S) or (1, T, S) bool.
    Returns (B, T, H, D).
    """
    B, T, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    Tp = int(np.ceil(T / 8) * 8)
    bk = min(block_k, int(np.ceil(S / 128) * 128))
    qf = _pad_to(q.transpose(0, 2, 1, 3), 8, axis=2)  # (B, H, Tp, D)
    qf = qf.reshape(B * H, Tp, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, S, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, S, D)
    mb = jnp.broadcast_to(mask, (B, T, S))
    mb = _pad_to(mb, 8, axis=1)
    mb = jnp.broadcast_to(mb[:, None], (B, H, Tp, S)).reshape(B * H, Tp, S)
    # pad S to the block size (padded slots masked out)
    kf = _pad_to(kf, bk, axis=1)
    vf = _pad_to(vf, bk, axis=1)
    mb = _pad_to(mb, bk, axis=2)
    out = tree_attention(qf, kf, vf, mb, block_k=bk, interpret=interpret)
    return out.reshape(B, H, Tp, D)[:, :, :T].transpose(0, 2, 1, 3)


def _fold_paged_arena(k_arena, v_arena, tbl, H):
    """Fold KV heads into the arena's block axis so the paged kernels see
    (Hkv*NBLK, block, hd) arenas and a per-(batch, head) table.

    k_arena, v_arena (NBLK, block, Hkv, hd); tbl (B, max_blocks) with -1 for
    unmapped (clamped to the trash block here).  Returns (kf, vf, tbl_f)
    with tbl_f (B*H, max_blocks) — head h of batch b reads physical block
    kv_head(h)*NBLK + tbl[b, j].  The transpose touches arena bytes once
    (the arena is the pool's physical footprint, already far smaller than
    the dense per-stream view the non-paged wrappers materialize)."""
    NB, block, Hkv, hd = k_arena.shape
    G = H // Hkv
    kf = k_arena.transpose(2, 0, 1, 3).reshape(Hkv * NB, block, hd)
    vf = v_arena.transpose(2, 0, 1, 3).reshape(Hkv * NB, block, hd)
    kvh = jnp.arange(H, dtype=jnp.int32) // G
    tbl_f = (kvh[None, :, None] * NB + jnp.clip(tbl, 0)[:, None, :]).reshape(-1, tbl.shape[1])
    return kf, vf, tbl_f.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gqa_paged_tree_attention(q, k_arena, v_arena, tbl, mask, *, interpret: bool = True):
    """Engine-layout tree attention over a paged KV pool.

    q (B, T, H, D); k_arena, v_arena (NBLK, block, Hkv, D); tbl
    (B, max_blocks) int32 (-1 = unmapped); mask (B, T, S) or (1, T, S) bool
    over logical slots, S = max_blocks*block (unmapped slots carry pos = -1
    upstream, so the mask is False there).  Returns (B, T, H, D)."""
    B, T, H, D = q.shape
    nb, block = tbl.shape[1], k_arena.shape[1]
    S = nb * block
    Tp = int(np.ceil(T / 8) * 8)
    qf = _pad_to(q.transpose(0, 2, 1, 3), 8, axis=2).reshape(B * H, Tp, D)
    kf, vf, tbl_f = _fold_paged_arena(k_arena, v_arena, tbl, H)
    mb = jnp.broadcast_to(mask, (B, T, S))
    mb = _pad_to(mb, 8, axis=1)
    mb = jnp.broadcast_to(mb[:, None], (B, H, Tp, S)).reshape(B * H, Tp, S)
    out = paged_tree_attention(qf, kf, vf, tbl_f, mb, interpret=interpret)
    return out.reshape(B, H, Tp, D)[:, :, :T].transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gqa_ragged_tree_attention(q, k_arena, v_arena, tbl, owner, mask, *,
                              interpret: bool = True):
    """Engine-layout RAGGED tree attention over a paged KV pool.

    q (N, H, D) — the flat node-major buffer of every active stream's tree
    (models/transformer.py ``ragged``); k_arena, v_arena
    (NBLK, block, Hkv, D); tbl (B, max_blocks) int32 (-1 = unmapped);
    owner (N,) int32 pool row per node; mask (N, S) bool over the owner
    row's logical slots.  Returns (N, H, D).

    Pads N up to a multiple of 8 (pad nodes: owner 0, mask all-False —
    their rows are garbage and sliced off) and hands the kernel one owner
    per 8-row Q tile; the engine's 8-aligned segment offsets guarantee
    tiles are owner-uniform for real nodes."""
    N, H, D = q.shape
    nb, block = tbl.shape[1], k_arena.shape[1]
    S = nb * block
    Np = int(np.ceil(N / 8) * 8)
    qp = _pad_to(q, 8, axis=0).transpose(1, 0, 2)  # (H, Np, D)
    op = _pad_to(owner.astype(jnp.int32), 8, axis=0)
    mp = _pad_to(mask, 8, axis=0).reshape(Np // 8, 8, S)
    owners_t = op.reshape(Np // 8, 8)[:, 0]
    kf, vf, tbl_f = _fold_paged_arena(k_arena, v_arena, tbl, H)
    out = ragged_paged_tree_attention(qp, kf, vf, tbl_f, owners_t, mp,
                                      interpret=interpret)
    return out.transpose(1, 0, 2)[:N]


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def gqa_paged_decode_attention(q, k_arena, v_arena, tbl, lengths, *, window: int = 0,
                               interpret: bool = True):
    """Engine-layout flash-decode over a paged KV pool.

    q (B, 1, H, D); k_arena, v_arena (NBLK, block, Hkv, D); tbl
    (B, max_blocks) int32; lengths (B,) int32.  Returns (B, 1, H, D)."""
    B, _, H, D = q.shape
    qf = jnp.broadcast_to(q.transpose(0, 2, 1, 3), (B, H, 8, D)).reshape(B * H, 8, D)
    kf, vf, tbl_f = _fold_paged_arena(k_arena, v_arena, tbl, H)
    lf = jnp.broadcast_to(lengths[:, None], (B, H)).reshape(B * H)
    out = paged_decode_attention(qf, kf, vf, tbl_f, lf, window=window, interpret=interpret)
    return out.reshape(B, H, 8, D)[:, :, :1].transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("block_k", "window", "interpret"))
def gqa_decode_attention(q, k, v, lengths, *, block_k: int = 1024, window: int = 0, interpret: bool = True):
    """Engine-layout flash-decode.

    q (B, 1, H, D); k, v (B, S, Hkv, D); lengths (B,) int32.
    Returns (B, 1, H, D).
    """
    B, _, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    bk = min(block_k, int(np.ceil(S / 128) * 128))
    qf = jnp.broadcast_to(q.transpose(0, 2, 1, 3), (B, H, 8, D)).reshape(B * H, 8, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, S, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, S, D)
    kf = _pad_to(kf, bk, axis=1)
    vf = _pad_to(vf, bk, axis=1)
    lf = jnp.broadcast_to(lengths[:, None], (B, H)).reshape(B * H, 1)
    out = decode_attention(qf, kf, vf, lf, block_k=bk, window=window, interpret=interpret)
    return out.reshape(B, H, 8, D)[:, :, :1].transpose(0, 2, 1, 3)
