"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every kernel in this package ships with an oracle here: a straight-line
jnp formulation of the same contract, bit-compared by the property tests
(tests/test_kernels.py, tests/test_commit_fused.py, tests/test_paged_pool.py)
and used as the dispatch fallback when ``use_pallas`` is off.  The pattern
is documented in docs/kernels.md."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def tree_attention_ref(q, k, v, mask):
    """q (BH, T, D); k, v (BH, S, D); mask (BH, T, S) -> (BH, T, D)."""
    d = q.shape[-1]
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32), k.astype(jnp.float32)) / (d**0.5)
    s = jnp.where(mask, s, NEG_INF)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bts,bsd->btd", w, v.astype(jnp.float32)).astype(q.dtype)


def commit_kv_ref(k, v, src, dst):
    """Gather-then-scatter oracle for the ring-compaction commit kernel.

    k, v: (L, B, Smax, Hkv, hd); src, dst: (B, P) int32.  Every source lane
    is read before any destination is written, so this is the ground truth
    the in-place sequential kernel must match under the hazard-free index
    contract (a src slot is never an earlier entry's dst slot, dst slots
    pairwise distinct; padding entries are identity copies with src == dst).
    """
    b = jnp.arange(k.shape[1])[:, None]
    kg = k[:, b, src]
    vg = v[:, b, src]
    return k.at[:, b, dst].set(kg), v.at[:, b, dst].set(vg)


def paged_gather_kv_ref(k_arena, v_arena, tbl):
    """Block-table KV gather oracle for the paged attention kernels.

    k_arena, v_arena: (NBLK, block, Hkv, hd) or (L, NBLK, block, Hkv, hd);
    tbl: (B, max_blocks) int32 (-1 = unmapped, clamped to the trash block 0).
    Returns the logical per-stream view (B, max_blocks*block, Hkv, hd)
    (with a leading L when the arena carries one).  Unmapped lanes hold
    trash content and must be masked by the caller (pos = -1 slots)."""
    phys = jnp.clip(tbl, 0)
    B, nb = phys.shape
    if k_arena.ndim == 5:  # leading layer axis
        block = k_arena.shape[2]
        kd = k_arena[:, phys].reshape((k_arena.shape[0], B, nb * block) + k_arena.shape[3:])
        vd = v_arena[:, phys].reshape((v_arena.shape[0], B, nb * block) + v_arena.shape[3:])
        return kd, vd
    block = k_arena.shape[1]
    kd = k_arena[phys].reshape((B, nb * block) + k_arena.shape[2:])
    vd = v_arena[phys].reshape((B, nb * block) + v_arena.shape[2:])
    return kd, vd


def ragged_tree_attention_ref(q, k_arena, v_arena, tbl, owner, mask):
    """Oracle for ops.gqa_ragged_tree_attention.

    q (N, H, D); k_arena, v_arena (NBLK, block, Hkv, D); tbl
    (B, max_blocks) int32 (-1 = unmapped); owner (N,) int32; mask (N, S)
    bool.  Gathers each node's OWNER-row logical KV view through the block
    table, then runs the plain masked softmax with GQA broadcast."""
    kd, vd = paged_gather_kv_ref(k_arena, v_arena, tbl[owner])  # (N, S, Hkv, hd)
    H = q.shape[1]
    G = H // kd.shape[2]
    kg = jnp.repeat(kd.transpose(0, 2, 1, 3), G, axis=1)  # (N, H, S, hd)
    vg = jnp.repeat(vd.transpose(0, 2, 1, 3), G, axis=1)
    d = q.shape[-1]
    s = jnp.einsum("nhd,nhsd->nhs", q.astype(jnp.float32), kg.astype(jnp.float32)) / (d**0.5)
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("nhs,nhsd->nhd", w, vg.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths, window: int = 0):
    """q (BH, R, D); k, v (BH, S, D); lengths (BH, 1) -> (BH, R, D)."""
    S = k.shape[1]
    slot = jnp.arange(S)[None, None, :]
    valid = slot < lengths[:, :, None]
    if window:
        valid = valid & (slot >= lengths[:, :, None] - window)
    d = q.shape[-1]
    s = jnp.einsum("brd,bsd->brs", q.astype(jnp.float32), k.astype(jnp.float32)) / (d**0.5)
    s = jnp.where(valid, s, NEG_INF)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("brs,bsd->brd", w, v.astype(jnp.float32)).astype(q.dtype)
