"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth)."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def tree_attention_ref(q, k, v, mask):
    """q (BH, T, D); k, v (BH, S, D); mask (BH, T, S) -> (BH, T, D)."""
    d = q.shape[-1]
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32), k.astype(jnp.float32)) / (d**0.5)
    s = jnp.where(mask, s, NEG_INF)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bts,bsd->btd", w, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths, window: int = 0):
    """q (BH, R, D); k, v (BH, S, D); lengths (BH, 1) -> (BH, R, D)."""
    S = k.shape[1]
    slot = jnp.arange(S)[None, None, :]
    valid = slot < lengths[:, :, None]
    if window:
        valid = valid & (slot >= lengths[:, :, None] - window)
    d = q.shape[-1]
    s = jnp.einsum("brd,bsd->brs", q.astype(jnp.float32), k.astype(jnp.float32)) / (d**0.5)
    s = jnp.where(valid, s, NEG_INF)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("brs,bsd->brd", w, v.astype(jnp.float32)).astype(q.dtype)
