"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth)."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def tree_attention_ref(q, k, v, mask):
    """q (BH, T, D); k, v (BH, S, D); mask (BH, T, S) -> (BH, T, D)."""
    d = q.shape[-1]
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32), k.astype(jnp.float32)) / (d**0.5)
    s = jnp.where(mask, s, NEG_INF)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bts,bsd->btd", w, v.astype(jnp.float32)).astype(q.dtype)


def commit_kv_ref(k, v, src, dst):
    """Gather-then-scatter oracle for the ring-compaction commit kernel.

    k, v: (L, B, Smax, Hkv, hd); src, dst: (B, P) int32.  Every source lane
    is read before any destination is written, so this is the ground truth
    the in-place sequential kernel must match under the hazard-free index
    contract (a src slot is never an earlier entry's dst slot, dst slots
    pairwise distinct; padding entries are identity copies with src == dst).
    """
    b = jnp.arange(k.shape[1])[:, None]
    kg = k[:, b, src]
    vg = v[:, b, src]
    return k.at[:, b, dst].set(kg), v.at[:, b, dst].set(vg)


def decode_attention_ref(q, k, v, lengths, window: int = 0):
    """q (BH, R, D); k, v (BH, S, D); lengths (BH, 1) -> (BH, R, D)."""
    S = k.shape[1]
    slot = jnp.arange(S)[None, None, :]
    valid = slot < lengths[:, :, None]
    if window:
        valid = valid & (slot >= lengths[:, :, None] - window)
    d = q.shape[-1]
    s = jnp.einsum("brd,bsd->brs", q.astype(jnp.float32), k.astype(jnp.float32)) / (d**0.5)
    s = jnp.where(valid, s, NEG_INF)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("brs,bsd->brd", w, v.astype(jnp.float32)).astype(q.dtype)
