"""OTLP solvers (Def. 3.2) — Appendix B of the paper — plus their exact
conditional output distributions (Appendix D generalised to the whole vocab)
and acceptance rates (Appendix C).

For each solver ``name`` we provide:

  ``<name>_solve(p, q, xs, rng)``       -> sampled output token (host, exact)
  ``<name>_output_dist(p, q, xs)``      -> (V,) exact distribution of the output
                                           *conditioned on the draft tokens xs*
  ``<name>_acceptance(p, q, k)``        -> P(output in {X_1..X_k}), X_i iid ~ q

Branching probabilities (Def. 5.3 / Appendix D) are ``output_dist[xs]``.

Losslessness (the OTLP property)  E_{xs ~ q^k}[output_dist(p,q,xs)] == p
is verified by exact enumeration in the tests.

Host-side numpy in float64: these functions are the *oracle* layer.  The
serving engine uses the jittable versions in ``repro.core.otlp_jax`` which are
tested against these.
"""
from __future__ import annotations

import numpy as np

_EPS = 1e-300


def _norm(v: np.ndarray) -> np.ndarray:
    s = v.sum()
    if s <= 0:
        # degenerate residual: caller guarantees it is weighted by 0 mass.
        out = np.zeros_like(v)
        out[0] = 1.0
        return out
    return v / s


def _pos(v: np.ndarray) -> np.ndarray:
    return np.maximum(v, 0.0)


# ---------------------------------------------------------------- NSS --------


def nss_output_dist(p, q, xs):
    return np.asarray(p, dtype=np.float64).copy()


def nss_solve(p, q, xs, rng):
    return int(rng.choice(len(p), p=_norm(np.asarray(p, dtype=np.float64))))


def nss_acceptance(p, q, k):
    return float(np.sum(p * (1.0 - (1.0 - q) ** k)))


# --------------------------------------------------------------- Naive -------


def naive_output_dist(p, q, xs):
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    x1 = int(xs[0])
    a = min(1.0, p[x1] / max(q[x1], _EPS))
    res = _norm(_pos(p - q))
    out = (1.0 - a) * res
    out[x1] += a
    return out


def naive_solve(p, q, xs, rng):
    x1 = int(xs[0])
    if rng.random() <= min(1.0, p[x1] / max(q[x1], _EPS)):
        return x1
    return int(rng.choice(len(p), p=_norm(_pos(np.asarray(p) - np.asarray(q)))))


def naive_acceptance(p, q, k):
    # Alg. 7: accept X1 naively; otherwise the residual may still land on one
    # of the other k-1 iid draft tokens.
    acc1 = float(np.sum(np.minimum(p, q)))
    res = _pos(p - q)  # unnormalised residual has mass 1 - acc1
    return acc1 + float(np.sum(res * (1.0 - (1.0 - q) ** (k - 1))))


# -------------------------------------------------------------- SpecTr -------


def _spectr_rho(p, q, k) -> float:
    """Binary search the division factor rho* on [1, k] (K-SEQ)."""

    def beta(rho):
        return float(np.sum(np.minimum(p / rho, q)))

    def g(rho):  # p_acc(rho) - rho * beta(rho), monotone decreasing
        b = beta(rho)
        return (1.0 - (1.0 - b) ** k) - rho * b

    if k == 1:
        return 1.0
    lo, hi = 1.0, float(k)
    if g(lo) <= 0:
        return lo
    if g(hi) >= 0:
        return hi
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if g(mid) > 0:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def _spectr_parts(p, q, k):
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    rho = _spectr_rho(p, q, k)
    cap = np.minimum(p / rho, q)  # per-token accepted mass (one round)
    beta = float(cap.sum())
    p_acc = 1.0 - (1.0 - beta) ** k
    gamma = p_acc / beta if beta > 0 else 0.0
    res = _norm(_pos(p - cap * gamma))
    return rho, cap, beta, p_acc, gamma, res


def spectr_output_dist(p, q, xs):
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    k = len(xs)
    rho, cap, beta, p_acc, gamma, res = _spectr_parts(p, q, k)
    a = np.array([min(1.0, p[x] / (rho * max(q[x], _EPS))) for x in xs])
    out = np.zeros_like(p)
    fail = 1.0
    for i, x in enumerate(xs):
        out[int(x)] += fail * a[i]
        fail *= 1.0 - a[i]
    out += fail * res
    return out


def spectr_solve(p, q, xs, rng):
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    rho, cap, beta, p_acc, gamma, res = _spectr_parts(p, q, len(xs))
    for x in xs:
        if rho * rng.random() <= p[int(x)] / max(q[int(x)], _EPS):
            return int(x)
    return int(rng.choice(len(p), p=res))


def spectr_acceptance(p, q, k):
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    rho, cap, beta, p_acc, gamma, res = _spectr_parts(p, q, k)
    r = _pos(q - p / rho) / max(1.0 - beta, _EPS)
    return p_acc + (1.0 - p_acc) * float(np.sum(res * (1.0 - (1.0 - r) ** k)))


# ----------------------------------------------------------- SpecInfer -------


def _specinfer_rounds(p, q, k):
    """Residuals p_0..p_k and accept vectors a_1..a_k (a_i = min(1, p_{i-1}/q))."""
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    ps = [p]
    avs = []
    cur = p
    for _ in range(k):
        avs.append(np.minimum(1.0, cur / np.maximum(q, _EPS)))
        cur = _norm(_pos(cur - q))
        ps.append(cur)
    return ps, avs


def specinfer_output_dist(p, q, xs):
    """Exact Alg. 14 recursion over sub-multisets of the draft tokens."""
    k = len(xs)
    ps, avs = _specinfer_rounds(p, q, k)
    V = len(ps[0])
    from functools import lru_cache

    @lru_cache(maxsize=None)
    def B(i: int, S: tuple) -> tuple:
        # returns the (V,) output distribution after i rejections with
        # remaining multiset S (|S| == k - i).
        if i == k:
            return tuple(ps[k])
        a = avs[i]  # round i+1 accept vector (uses residual p_i)
        out = np.zeros(V, dtype=np.float64)
        m = len(S)
        for j in range(m):
            t = S[j]
            rest = tuple(sorted(S[:j] + S[j + 1 :]))
            out[t] += a[t] / m
            out += (1.0 - a[t]) / m * np.asarray(B(i + 1, rest))
        return tuple(out)

    return np.asarray(B(0, tuple(sorted(int(x) for x in xs))))


def specinfer_solve(p, q, xs, rng):
    p = np.asarray(p, dtype=np.float64).copy()
    q = np.asarray(q, dtype=np.float64)
    S = [int(x) for x in xs]
    while S:
        x = S[int(rng.integers(len(S)))]
        if rng.random() <= min(1.0, p[x] / max(q[x], _EPS)):
            return x
        p = _norm(_pos(p - q))
        S.remove(x)
    return int(rng.choice(len(p), p=_norm(p)))


def specinfer_acceptance(p, q, k):
    # Alg. 9 as written.
    p = np.asarray(p, dtype=np.float64).copy()
    q = np.asarray(q, dtype=np.float64)
    p_rej = 1.0
    m = np.ones_like(p)
    for _ in range(k):
        r = float(np.sum(np.minimum(p, q)))
        p_rej *= 1.0 - r
        m = m * (1.0 - _pos(q - p) / max(1.0 - r, _EPS))
        p = _norm(_pos(p - q))
    return (1.0 - p_rej) + p_rej * float(np.sum(p * (1.0 - m)))


# -------------------------------------------------------------- Khisti -------
#
# Canonical two-stage decomposition (Khisti et al., 2025): stage 1 selects a
# token with marginal r (an importance-weighted distribution realisable from k
# iid q-draws); stage 2 runs single-draft naive speculative sampling with
# proposal r.  We realise stage 1 with the K-SEQ OTLP solver *targeting r*:
# since K-SEQ is itself an OTLP solver, its output follows r exactly, so the
# composite is exactly lossless.  r is the water-filled optimum of
# max sum_x min(p, r)  s.t.  r(x) <= 1 - (1 - q(x))^k  (the availability bound).
# See DESIGN.md §7 for how this relates to the published construction.


def khisti_importance_sample(p, q, k):
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    u = 1.0 - (1.0 - q) ** k  # P(token available among the k draws)
    r = np.minimum(p, u)
    deficit = 1.0 - r.sum()
    head = u - r
    hs = head.sum()
    if deficit > 1e-15 and hs > 0:
        r = r + deficit * head / hs
    return _norm(r)


def khisti_output_dist(p, q, xs):
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    r = khisti_importance_sample(p, q, len(xs))
    d1 = spectr_output_dist(r, q, xs)  # stage-1 selection dist given xs
    a = np.minimum(1.0, p / np.maximum(r, _EPS))
    res = _norm(_pos(p - r))
    keep = d1 * a
    return keep + (1.0 - keep.sum()) * res


def khisti_solve(p, q, xs, rng):
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    r = khisti_importance_sample(p, q, len(xs))
    x = spectr_solve(r, q, xs, rng)
    if rng.random() <= min(1.0, p[x] / max(r[x], _EPS)):
        return x
    return int(rng.choice(len(p), p=_norm(_pos(p - r))))


def khisti_acceptance(p, q, k, n_mc: int = 96):
    """Acceptance of the two-stage construction.

    Alg. 10's closed-form lower bound (sum min(p, r)) assumes stage-1 always
    selects a *drafted* token (true for the published tournament).  Our
    stage-1 (K-SEQ targeting r; see module docstring) may output non-drafted
    tokens, so we compute the acceptance with exact inner output
    distributions and a seeded Monte Carlo outer expectation over drafts.
    """
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    V = len(q)
    if V**k <= 4096:  # exact outer enumeration when feasible
        import itertools

        acc = 0.0
        for xs in itertools.product(range(V), repeat=k):
            w = float(np.prod([q[x] for x in xs]))
            if w > 0:
                d = khisti_output_dist(p, q, list(xs))
                acc += w * sum(d[int(x)] for x in set(xs))
        return acc
    rng = np.random.default_rng(12345)
    acc = 0.0
    for _ in range(n_mc):
        xs = list(rng.choice(V, size=k, p=_norm(q)))
        d = khisti_output_dist(p, q, xs)
        acc += sum(d[int(x)] for x in set(xs))
    return acc / n_mc


def khisti_acceptance_lower(p, q, k):
    """Alg. 10 as printed: sum_t min(p, r) (valid for the tournament form)."""
    r = khisti_importance_sample(p, q, k)
    return float(np.sum(np.minimum(np.asarray(p, dtype=np.float64), r)))


# ------------------------------------------------------------ registry -------

OTLP_SOLVERS = {
    "nss": (nss_solve, nss_output_dist, nss_acceptance),
    "naive": (naive_solve, naive_output_dist, naive_acceptance),
    "spectr": (spectr_solve, spectr_output_dist, spectr_acceptance),
    "specinfer": (specinfer_solve, specinfer_output_dist, specinfer_acceptance),
    "khisti": (khisti_solve, khisti_output_dist, khisti_acceptance),
}

# NaiveTree is the Naive solver used in multi-path traversal (Table 1): the
# solver is identical; the tree walk treats all children as candidates.
OTLP_SOLVERS["naivetree"] = OTLP_SOLVERS["naive"]


def branching_probs(name: str, p, q, xs) -> np.ndarray:
    """Def. 5.3 / Appendix D: probability the solver outputs each draft token."""
    _, output_dist, _ = OTLP_SOLVERS[name]
    d = output_dist(p, q, xs)
    return np.asarray([d[int(x)] for x in xs])


def acceptance_rate(name: str, p, q, k: int) -> float:
    """Def. 5.1 / Appendix C."""
    _, _, acc = OTLP_SOLVERS[name]
    return acc(np.asarray(p, dtype=np.float64), np.asarray(q, dtype=np.float64), k)
