"""Draft-tree data structures.

A draft tree (Def. 3.1) is stored flat:  node 0 is the root (the current
context head, no token of its own); every other node ``i`` holds the token
that extends its parent's context.  Drafted paths are kept *unmerged*: if two
i.i.d. paths draw the same token under the same parent they remain separate
nodes.  This is exactly the multiset child-list semantics of Def. 3.1 — every
algorithm here treats the child list of a context as the multiset of child
tokens across all drafted nodes sharing that context (the "active set" of
nodes that represent it).

Delayed trees (Def. 5.2) are the (K, L1, L2) family: a trunk path of length
L1 followed by K i.i.d. branches of length L2.  K=?, L1=0 recovers plain
i.i.d. root rollouts; K=1 recovers a single path of length L1+L2.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class DraftTree:
    """Flat draft tree.  N nodes including the root (index 0).

    tokens[i]  : token extending parent context (tokens[0] is unused, -1)
    parent[i]  : parent node index (parent[0] == -1)
    depth[i]   : root-distance (depth[0] == 0)
    q[i]       : draft next-token distribution *at* node i's context, shape (V,)
    p[i]       : target next-token distribution at node i's context, shape (V,)
    """

    tokens: np.ndarray
    parent: np.ndarray
    depth: np.ndarray
    q: np.ndarray
    p: np.ndarray | None = None
    # path order for traversal tie-breaks: order[i] = index of the drafted
    # path that created node i (trunk nodes get 0).
    path_id: np.ndarray | None = None

    @property
    def n_nodes(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def vocab(self) -> int:
        return int(self.q.shape[-1])

    def children(self, node: int) -> list[int]:
        return [i for i in range(self.n_nodes) if self.parent[i] == node]

    def children_of_set(self, nodes: list[int]) -> list[int]:
        s = set(nodes)
        return [i for i in range(self.n_nodes) if self.parent[i] in s]

    def path_tokens(self, node: int) -> list[int]:
        out = []
        while node != 0:
            out.append(int(self.tokens[node]))
            node = int(self.parent[node])
        return out[::-1]

    def max_depth(self) -> int:
        return int(self.depth.max())


def delayed_tree_node_count(K: int, L1: int, L2: int) -> int:
    return 1 + L1 + K * L2


def build_delayed_tree(
    rng: np.random.Generator,
    q_fn,
    K: int,
    L1: int,
    L2: int,
    root_context: tuple[int, ...] = (),
) -> DraftTree:
    """Draft a (K, L1, L2)-delayed tree from draft model ``q_fn``.

    ``q_fn(context_tuple) -> (V,) numpy distribution``.  Host-side reference
    implementation used by the algorithm library and tests; the serving
    engine has a batched JAX equivalent.
    """
    tokens = [-1]
    parent = [-1]
    depth = [0]
    pid = [0]
    qs = [np.asarray(q_fn(root_context), dtype=np.float64)]

    def _sample(dist):
        return int(rng.choice(len(dist), p=dist / dist.sum()))

    # trunk
    ctx = tuple(root_context)
    node = 0
    for _ in range(L1):
        t = _sample(qs[node])
        ctx = ctx + (t,)
        tokens.append(t)
        parent.append(node)
        depth.append(depth[node] + 1)
        pid.append(0)
        qs.append(np.asarray(q_fn(ctx), dtype=np.float64))
        node = len(tokens) - 1
    branch_node, branch_ctx = node, ctx
    # K i.i.d. branches
    for k in range(K):
        node, ctx = branch_node, branch_ctx
        for _ in range(L2):
            t = _sample(qs[node])
            ctx = ctx + (t,)
            tokens.append(t)
            parent.append(node)
            depth.append(depth[node] + 1)
            pid.append(k)
            qs.append(np.asarray(q_fn(ctx), dtype=np.float64))
            node = len(tokens) - 1
    return DraftTree(
        tokens=np.asarray(tokens, dtype=np.int64),
        parent=np.asarray(parent, dtype=np.int64),
        depth=np.asarray(depth, dtype=np.int64),
        q=np.stack(qs, axis=0),
        path_id=np.asarray(pid, dtype=np.int64),
    )


def attach_target(tree: DraftTree, p_fn, root_context: tuple[int, ...] = ()) -> DraftTree:
    """Fill ``tree.p`` by evaluating the target distribution at every node
    (the host-side analogue of the batched tree-attention target pass)."""
    ps = []
    for i in range(tree.n_nodes):
        ctx = tuple(root_context) + tuple(tree.path_tokens(i))
        ps.append(np.asarray(p_fn(ctx), dtype=np.float64))
    tree.p = np.stack(ps, axis=0)
    return tree


def tree_ancestor_mask(parent: np.ndarray) -> np.ndarray:
    """(N, N) boolean mask: mask[i, j] == True iff j is an ancestor of i or i==j.

    This is the attention mask of the speculation block in the target tree
    pass (token i may attend to token j).
    """
    n = parent.shape[0]
    mask = np.eye(n, dtype=bool)
    for i in range(n):
        j = int(parent[i])
        while j >= 0:
            mask[i, j] = True
            j = int(parent[j])
    return mask
