"""Tree verification.

Top-down OT-based traversal (Sec. 3.2): starting at the root, repeatedly run
the OTLP solver on (p, q, child tokens); move to the child matching the output
token, or terminate emitting it as the correction token.

Merged-context semantics: drafted paths are stored unmerged (see trees.py), so
the traversal tracks the *active set* of nodes sharing the current context.
The child list is the multiset of child tokens over the active set — exactly
the multiplicity semantics of Def. 3.1.

Also: single-path Naive and Block Verification (BV, Sun et al. 2024c) with the
nested single-uniform coupling:

    w_0 = 1,  w_i = min(1, w_{i-1} * p_i(x_i) / q_i(x_i))
    P(tau >= i) = w_i           (single U; tau = max{i : w_i >= U})
    correction at tau = i < L:  r_i ∝ (w_i * p_{i+1}(.) - q_{i+1}(.) * w_{i+1}(.))_+
                              = (w_i * p_{i+1} - q_{i+1})_+   [since w_{i+1}(s)
                                = min(1, w_i p(s)/q(s))]
    correction at tau = L:      p_{L+1}

which reduces to naive speculative sampling's accept/residual at L=1.

The module also owns the *verifier registry* — the single place a
verification algorithm is given a name.  Every engine mode (single-stream,
batched, sharded, pipelined) resolves ``EngineConfig.verifier`` through
``get_verifier``, and the losslessness property tests, the Table-1 matrix
harness and ``launch/serve.py --verifier`` all enumerate ``VERIFIERS`` — a
new verifier registered here is tested, benchmarked and servable by
construction (docs/verifiers.md).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Protocol, runtime_checkable

import numpy as np

from repro.core.otlp import OTLP_SOLVERS, _norm, _pos
from repro.core.trees import DraftTree


# ------------------------------------------------------- top-down OT walk ----


def verify_topdown(tree: DraftTree, solver: str, rng: np.random.Generator):
    """Run an OT-based verifier on a drafted tree with target dists attached.

    Returns (accepted_tokens, correction_token): the emitted block is
    accepted_tokens + [correction_token].
    """
    assert tree.p is not None, "attach_target first"
    solve, _, _ = OTLP_SOLVERS[solver]
    active = [0]
    accepted: list[int] = []
    while True:
        kids = tree.children_of_set(active)
        node = active[0]
        p, q = tree.p[node], tree.q[node]
        if not kids:
            return accepted, int(rng.choice(len(p), p=_norm(np.asarray(p))))
        xs = [int(tree.tokens[c]) for c in kids]
        y = solve(p, q, xs, rng)
        matches = [c for c in kids if int(tree.tokens[c]) == y]
        if not matches:
            return accepted, int(y)
        accepted.append(int(y))
        active = matches


def verify_topdown_output_dist(tree: DraftTree, solver: str) -> dict:
    """Exact distribution over emitted blocks, conditioned on the tree.

    Returns {tuple(block_tokens): probability}.  Used by the enumeration
    losslessness tests (expectation over trees must equal the target process).
    """
    assert tree.p is not None
    _, output_dist, _ = OTLP_SOLVERS[solver]
    out: dict = {}

    def rec(active: list[int], prefix: tuple, mass: float):
        if mass <= 0:
            return
        kids = tree.children_of_set(active)
        node = active[0]
        p, q = tree.p[node], tree.q[node]
        if not kids:
            for t, pt in enumerate(p):
                if pt > 0:
                    key = prefix + (t,)
                    out[key] = out.get(key, 0.0) + mass * float(pt)
            return
        xs = [int(tree.tokens[c]) for c in kids]
        d = output_dist(p, q, xs)
        xs_set = set(xs)
        for t, dt in enumerate(d):
            if dt <= 0:
                continue
            if t in xs_set:
                rec([c for c in kids if int(tree.tokens[c]) == t], prefix + (t,), mass * float(dt))
            else:
                key = prefix + (t,)
                out[key] = out.get(key, 0.0) + mass * float(dt)

    rec([0], (), 1.0)
    return out


# ------------------------------------------------ single-path Naive / BV -----


def _single_path(tree: DraftTree) -> list[int]:
    path = []
    node = 0
    while True:
        kids = tree.children(node)
        if not kids:
            return path
        assert len(kids) == 1, "single-path verifier on a branching tree"
        node = kids[0]
        path.append(node)


def verify_naive_single(tree: DraftTree, rng: np.random.Generator):
    """Original speculative sampling on a single-path tree (Sec. 3.1)."""
    assert tree.p is not None
    path = _single_path(tree)
    accepted: list[int] = []
    node = 0
    for v in path:
        t = int(tree.tokens[v])
        p, q = tree.p[node], tree.q[node]
        if rng.random() <= min(1.0, p[t] / max(q[t], 1e-300)):
            accepted.append(t)
            node = v
        else:
            corr = int(rng.choice(len(p), p=_norm(_pos(np.asarray(p) - np.asarray(q)))))
            return accepted, corr
    return accepted, int(rng.choice(tree.vocab, p=_norm(np.asarray(tree.p[node]))))


def verify_bv(tree: DraftTree, rng: np.random.Generator):
    """Block Verification on a single-path tree.

    BV is exactly Traversal Verification restricted to a path (the K=1
    reduction holds by construction): the whole chain is the trunk, the
    branch stage is empty, and the trunk stage performs the conditional
    leaf-to-root climb with nested weights.  See traversal.py for the math.
    """
    from repro.core.traversal import verify_traversal

    _single_path(tree)  # asserts path structure
    return verify_traversal(tree, rng)


def verify_bv_output_dist(tree: DraftTree) -> dict:
    """Exact emitted-block distribution of BV conditioned on the tree."""
    from repro.core.traversal import verify_traversal_output_dist

    _single_path(tree)
    return verify_traversal_output_dist(tree)


# ----------------------------------------------------------------- registry --


@runtime_checkable
class Verifier(Protocol):
    """The pluggable verifier contract.

    ``verify``      samples one verification round on a target-attached tree
                    and returns (accepted_tokens, correction_token).
    ``output_dist`` is the *exact* conditional law of the emitted block given
                    the tree, {block_tuple: probability} — the object the
                    enumeration losslessness tests integrate over trees.
    """

    name: str

    def verify(self, tree: DraftTree, rng: np.random.Generator) -> tuple[list[int], int]: ...

    def output_dist(self, tree: DraftTree) -> dict: ...


@dataclass(frozen=True)
class VerifierSpec:
    """Registry entry.  ``verify``/``output_dist`` are plain callables with
    the Verifier protocol signatures.

    multipath : handles branching trees (K >= 2); single-path verifiers
                (naive_single, bv) require K == 1 drafts.
    on_device : has a batched on-device OT solve (core/otlp_jax.py) behind
                ``EngineConfig.verify_on_device`` — the top-down OT family.
    cite      : short provenance string surfaced by docs and the matrix
                harness.
    """

    name: str
    _verify: Callable = field(repr=False)
    _output_dist: Callable = field(repr=False)
    multipath: bool = True
    on_device: bool = False
    cite: str = ""

    def verify(self, tree: DraftTree, rng: np.random.Generator):
        return self._verify(tree, rng)

    def output_dist(self, tree: DraftTree) -> dict:
        return self._output_dist(tree)


VERIFIERS: dict[str, VerifierSpec] = {}


def register_verifier(spec: VerifierSpec) -> VerifierSpec:
    """Register a verifier by name.  Fails loudly on duplicates — shadowing a
    verification algorithm silently is never what anyone wants."""
    if spec.name in VERIFIERS:
        raise ValueError(f"verifier {spec.name!r} already registered")
    VERIFIERS[spec.name] = spec
    return spec


def get_verifier(name: str) -> VerifierSpec:
    """Resolve a verifier by name; unknown names list the registry."""
    try:
        return VERIFIERS[name]
    except KeyError:
        raise ValueError(
            f"unknown verifier {name!r}; registered: {', '.join(sorted(VERIFIERS))}"
        ) from None


def verifier_names() -> list[str]:
    return sorted(VERIFIERS)


def _register_builtins():
    from repro.core.greedy_bv import greedy_mpbv_output_dist, verify_greedy_mpbv
    from repro.core.traversal import verify_traversal, verify_traversal_output_dist
    from repro.core.univer import univer_output_dist, verify_univer

    _OT_CITES = {
        "nss": "NSS OT coupling (paper Sec. 3.2)",
        "naive": "k-draw naive coupling (paper Sec. 3.2)",
        "naivetree": "alias of naive (tree form)",
        "spectr": "SpecTr (Sun et al., 2023)",
        "specinfer": "SpecInfer (Miao et al., 2023)",
        "khisti": "two-stage importance coupling (Khisti et al., 2024)",
    }
    for solver in _OT_CITES:

        def _v(tree, rng, _s=solver):
            return verify_topdown(tree, _s, rng)

        def _d(tree, _s=solver):
            return verify_topdown_output_dist(tree, _s)

        register_verifier(VerifierSpec(solver, _v, _d, multipath=True, on_device=True,
                                       cite=_OT_CITES[solver]))
    register_verifier(VerifierSpec(
        "traversal", verify_traversal, verify_traversal_output_dist,
        multipath=True, cite="Traversal Verification (Weng et al., 2025)"))
    register_verifier(VerifierSpec(
        "naive_single", verify_naive_single, _naive_single_output_dist,
        multipath=False, cite="speculative sampling (Leviathan et al., 2023)"))
    register_verifier(VerifierSpec(
        "bv", verify_bv, verify_bv_output_dist,
        multipath=False, cite="Block Verification (Sun et al., 2024)"))
    register_verifier(VerifierSpec(
        "univer", verify_univer, univer_output_dist,
        multipath=True, cite="UniVer unified multi-step x multi-draft (arXiv 2605.04543)"))
    register_verifier(VerifierSpec(
        "greedy_mpbv", verify_greedy_mpbv, greedy_mpbv_output_dist,
        multipath=True, cite="Greedy Multi-Path Block Verification (arXiv 2602.16961)"))


def _naive_single_output_dist(tree: DraftTree) -> dict:
    """Exact emitted-block law of naive single-path speculative sampling."""
    path = _single_path(tree)
    out: dict = {}
    node, mass = 0, 1.0
    prefix: tuple = ()
    for v in path:
        t = int(tree.tokens[v])
        p, q = np.asarray(tree.p[node], np.float64), np.asarray(tree.q[node], np.float64)
        a = min(1.0, float(p[t]) / max(float(q[t]), 1e-300))
        resid = _pos(p - q)
        if a < 1.0 and resid.sum() > 0:
            resid = _norm(resid)
            for s, ps in enumerate(resid):
                if ps > 0:
                    key = prefix + (s,)
                    out[key] = out.get(key, 0.0) + mass * (1.0 - a) * float(ps)
        mass *= a
        prefix = prefix + (t,)
        node = v
    p = np.asarray(tree.p[node], np.float64)
    for s, ps in enumerate(p):
        if ps > 0:
            key = prefix + (s,)
            out[key] = out.get(key, 0.0) + mass * float(ps)
    return out


_register_builtins()
