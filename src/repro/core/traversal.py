"""Traversal Verification — bottom-up tree verification (Weng et al., 2025).

The paper under reproduction specifies Traversal only by its properties (the
sole bottom-up multi-path verifier; reduces to Block Verification at K=1), so
we derive the scheme from first principles for delayed trees (Def. 5.2, which
subsume i.i.d. root rollouts at L1=0) and prove it lossless by exact
enumeration (tests/test_lossless.py).  Construction:

Trunk:  nested block weights  w_0 = 1, w_i = min(1, w_{i-1} p_i(t_i)/q_i(t_i)),
        W = w_{L1}.

Branch stage (leaf-first, branches in drafted order):  maintain the
*unnormalised residual target measure* mu at the branch node, initialised to
mu_1 = W * p(.|branch ctx)  (mass W), and the reach probability rho_1 = 1.
For branch k with tokens s_1..s_{L2}:

    v_1 = min(1, mu_k(s_1) / (rho_k q_b(s_1)))          [first-step weight]
    v_j = min(1, v_{j-1} p_j(s_j)/q_j(s_j))             [deeper, fresh target]

    climb from the leaf with *conditional* acceptances
        alpha_{L2} = v_{L2}
        alpha_j    = (v_j - e_{j+1}) / (1 - e_{j+1}),
        e_{j+1}    = sum_s min(v_j p(s|node_j), q(s|node_j))
    accepting depth j emits the whole root path (trunk + branch prefix) with
    correction  ~ p(.|leaf)                       if j = L2
               ~ norm((v_j p(.|node_j) - q(.|node_j))_+)   otherwise.

    On full rejection:  a_k = sum_s min(mu_k(s)/rho_k, q_b(s)),
        mu_{k+1} = (mu_k - rho_k q_b)_+ ,   rho_{k+1} = rho_k (1 - a_k).

Trunk stage (after all branches reject):  alpha_{L1} = mass(mu_{K+1})/rho_{K+1}
with correction norm(mu_{K+1}); deeper trunk levels climb with the standard
conditional weights (e_i as above), corrections norm((w_i p - q)_+), and the
root correction is norm((p - q)_+).

At K=1 every quantity collapses to Block Verification on the full path; at
L1=0, L2=1 the branch stage is exactly (ordered) SpecInfer.
"""
from __future__ import annotations

import numpy as np

from repro.core.otlp import _norm, _pos
from repro.core.trees import DraftTree

_EPS = 1e-300


# --------------------------------------------------------------- structure ---


def delayed_structure(tree: DraftTree):
    """Decompose into (trunk_nodes, branch_root, [branch_paths]) using path_id
    when available (needed to find the L1 boundary of K=1 trees)."""
    if tree.path_id is not None:
        # trunk nodes: path_id == 0 nodes that are ancestors of all leaves —
        # identify branch root as deepest node lying on every drafted path.
        # For delayed trees built by this framework: trunk = nodes whose
        # subtree contains every leaf.
        n = tree.n_nodes
        kids_of = [[] for _ in range(n)]
        for i in range(1, n):
            kids_of[int(tree.parent[i])].append(i)
        # count leaves under each node
        leaves_under = [0] * n
        order = sorted(range(n), key=lambda i: -int(tree.depth[i]))
        total_leaves = 0
        for i in order:
            if not kids_of[i]:
                leaves_under[i] = 1
            else:
                leaves_under[i] = sum(leaves_under[c] for c in kids_of[i])
        total_leaves = leaves_under[0]
        trunk = []
        node = 0
        while kids_of[node]:
            on_all = [c for c in kids_of[node] if leaves_under[c] == total_leaves]
            if len(kids_of[node]) == 1 and on_all:
                # unique child containing all leaves: still trunk *unless* the
                # path structure says branching starts here (K=1 delayed tree)
                c = on_all[0]
                # branch nodes of path k>0 never sit on the trunk; for K=1 we
                # cannot distinguish — treat the whole chain as trunk + use
                # n_branch hints from metadata when present.
                trunk.append(c)
                node = c
            else:
                break
        branch_root = node
    else:
        trunk = []
        node = 0
        while True:
            kids = tree.children(node)
            if len(kids) != 1:
                break
            trunk.append(kids[0])
            node = kids[0]
        branch_root = node
    branches = []
    for c in tree.children(branch_root):
        if c in trunk:
            continue
        path = [c]
        cur = c
        while True:
            k2 = tree.children(cur)
            if not k2:
                break
            assert len(k2) == 1, "delayed-tree branches must be simple paths"
            cur = k2[0]
            path.append(cur)
        branches.append(path)
    return trunk, branch_root, branches


def _tok(tree, v):
    return int(tree.tokens[v])


def _pq(tree, node):
    return (
        np.asarray(tree.p[node], dtype=np.float64),
        np.asarray(tree.q[node], dtype=np.float64),
    )


def _trunk_weights(tree, trunk):
    w, out = 1.0, []
    for v in trunk:
        p, q = _pq(tree, int(tree.parent[v]))
        t = _tok(tree, v)
        w = min(1.0, w * p[t] / max(q[t], _EPS))
        out.append(w)
    return np.asarray(out)


def _branch_weights(tree, path, v1):
    out = [v1]
    v = v1
    for node in path[1:]:
        p, q = _pq(tree, int(tree.parent[node]))
        t = _tok(tree, node)
        v = min(1.0, v * p[t] / max(q[t], _EPS))
        out.append(v)
    return np.asarray(out)


def _climb_masses(tree, path, vs):
    """P(accept depth j | segment reached), j = 1..len(path); conditional
    leaf-to-root climb.  Returns (masses, reject_prob)."""
    L = len(path)
    alphas = np.zeros(L)
    alphas[L - 1] = vs[L - 1]
    for j in range(L - 1, 0, -1):  # depth j (1-indexed), node path[j-1]
        node = path[j - 1]
        p, q = _pq(tree, node)
        e = float(np.sum(np.minimum(vs[j - 1] * p, q)))
        alphas[j - 1] = (vs[j - 1] - e) / max(1.0 - e, _EPS) if e < 1.0 else 0.0
        alphas[j - 1] = min(max(alphas[j - 1], 0.0), 1.0)
    masses = np.zeros(L)
    surv = 1.0
    for j in range(L, 0, -1):
        masses[j - 1] = surv * alphas[j - 1]
        surv *= 1.0 - alphas[j - 1]
    return masses, surv


def _segment_correction(tree, path, vs, j):
    """Correction distribution on accepting depth j (1-indexed) of a path."""
    node = path[j - 1]
    p, q = _pq(tree, node)
    if j == len(path):
        return _norm(p)
    return _norm(_pos(vs[j - 1] * p - q))


def verify_traversal(tree: DraftTree, rng: np.random.Generator):
    """Sample the Traversal verifier.  Returns (accepted_tokens, correction)."""
    assert tree.p is not None
    trunk, broot, branches = delayed_structure(tree)
    tw = _trunk_weights(tree, trunk)
    W = float(tw[-1]) if len(tw) else 1.0
    pb, qb = _pq(tree, broot)

    mu = W * pb  # unnormalised residual measure at the branch node
    rho = 1.0
    for path in branches:
        t1 = _tok(tree, path[0])
        v1 = min(1.0, mu[t1] / max(rho * qb[t1], _EPS))
        vs = _branch_weights(tree, path, v1)
        masses, rej = _climb_masses(tree, path, vs)
        u = rng.random()
        csum = 0.0
        accepted_j = 0
        # climb leaf-to-root: realise the conditional Bernoullis via masses
        for j in range(len(path), 0, -1):
            csum += masses[j - 1]
            if u < csum:
                accepted_j = j
                break
        if accepted_j:
            node = path[accepted_j - 1]
            corr = int(rng.choice(tree.vocab, p=_segment_correction(tree, path, vs, accepted_j)))
            return tree.path_tokens(node), corr
        a_k = float(np.sum(np.minimum(mu / max(rho, _EPS), qb)))
        mu = _pos(mu - rho * qb)
        rho *= 1.0 - a_k
    # trunk stage
    mass_mu = float(mu.sum())
    if trunk:
        alpha_top = min(1.0, mass_mu / max(rho, _EPS))
        if rng.random() <= alpha_top:
            corr = int(rng.choice(tree.vocab, p=_norm(mu)))
            return tree.path_tokens(trunk[-1]), corr
        # climb remaining trunk with standard conditional weights
        tws = np.concatenate([[1.0], tw])
        for j in range(len(trunk) - 1, 0, -1):
            node = trunk[j - 1]
            p, q = _pq(tree, node)
            e = float(np.sum(np.minimum(tws[j] * p, q)))
            alpha = (tws[j] - e) / max(1.0 - e, _EPS) if e < 1.0 else 0.0
            if rng.random() <= min(max(alpha, 0.0), 1.0):
                corr = int(rng.choice(tree.vocab, p=_norm(_pos(tws[j] * p - q))))
                return tree.path_tokens(node), corr
        p0, q0 = _pq(tree, 0)
        return [], int(rng.choice(tree.vocab, p=_norm(_pos(p0 - q0))))
    # L1 == 0: no trunk; emit root correction from the residual measure
    return [], int(rng.choice(tree.vocab, p=_norm(mu) if mass_mu > 0 else _norm(_pos(pb - qb))))


def verify_traversal_output_dist(tree: DraftTree) -> dict:
    """Exact emitted-block distribution conditioned on the drafted tree."""
    assert tree.p is not None
    trunk, broot, branches = delayed_structure(tree)
    tw = _trunk_weights(tree, trunk)
    W = float(tw[-1]) if len(tw) else 1.0
    pb, qb = _pq(tree, broot)
    out: dict = {}

    def add(prefix, dist, mass):
        if mass <= 0:
            return
        for t, pt in enumerate(dist):
            if pt > 0:
                key = tuple(prefix) + (t,)
                out[key] = out.get(key, 0.0) + mass * float(pt)

    mu = W * pb
    rho = 1.0
    reach = 1.0  # P(branch stage reaches branch k)
    for path in branches:
        t1 = _tok(tree, path[0])
        v1 = min(1.0, mu[t1] / max(rho * qb[t1], _EPS))
        vs = _branch_weights(tree, path, v1)
        masses, rej = _climb_masses(tree, path, vs)
        for j in range(len(path), 0, -1):
            node = path[j - 1]
            add(tree.path_tokens(node), _segment_correction(tree, path, vs, j), reach * masses[j - 1])
        reach *= rej
        a_k = float(np.sum(np.minimum(mu / max(rho, _EPS), qb)))
        mu = _pos(mu - rho * qb)
        rho *= 1.0 - a_k
    mass_mu = float(mu.sum())
    if trunk:
        alpha_top = min(1.0, mass_mu / max(rho, _EPS))
        add(tree.path_tokens(trunk[-1]), _norm(mu), reach * alpha_top)
        surv = reach * (1.0 - alpha_top)
        tws = np.concatenate([[1.0], tw])
        for j in range(len(trunk) - 1, 0, -1):
            node = trunk[j - 1]
            p, q = _pq(tree, node)
            e = float(np.sum(np.minimum(tws[j] * p, q)))
            alpha = min(max((tws[j] - e) / max(1.0 - e, _EPS) if e < 1.0 else 0.0, 0.0), 1.0)
            add(tree.path_tokens(node), _norm(_pos(tws[j] * p - q)), surv * alpha)
            surv *= 1.0 - alpha
        p0, q0 = _pq(tree, 0)
        add([], _norm(_pos(p0 - q0)), surv)
    else:
        if mass_mu > 0:
            add([], mu / mass_mu, reach)
        else:
            add([], _norm(_pos(pb - qb)), reach)
    return out
