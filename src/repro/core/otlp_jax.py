"""Jittable on-device OTLP solvers + whole-tree verification.

The numpy implementations in ``otlp.py``/``verify.py`` are the float64
oracles; these jnp versions keep the entire verify step on-device (no
host sync per node), which is the TPU-native deployment path (DESIGN.md §4):
on GPU systems verification runs on the host, but TPU host round-trips cost
more than the verify math.

All functions are shape-static and jit/vmap-compatible:

    solve_<name>(p, q, xs, key)              -> token (int32)
    verify_topdown_jax(tree, p, q, key, ...) -> (accepted mask, correction)

Trees use the flat fixed-size layout of ``core.trees`` (parent == -1 beyond
``n_nodes``).  Tested against the numpy oracles in tests/test_otlp_jax.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.sampling import sample_categorical

_EPS = 1e-30


def _norm(v):
    s = jnp.sum(v)
    safe = jnp.where(s > 0, v / jnp.maximum(s, _EPS), jnp.ones_like(v) / v.shape[-1])
    return safe


def _pos(v):
    return jnp.maximum(v, 0.0)


# ------------------------------------------------------------- solvers -------


def solve_nss(p, q, xs, valid, key):
    return sample_categorical(key, _norm(p)).astype(jnp.int32)


def solve_naive(p, q, xs, valid, key):
    k1, k2, k3 = jax.random.split(key, 3)
    x1 = xs[0]
    a = jnp.minimum(1.0, p[x1] / jnp.maximum(q[x1], _EPS))
    res = _norm(_pos(p - q))
    accept = jax.random.uniform(k1) <= a
    alt = sample_categorical(k2, res).astype(jnp.int32)
    return jnp.where(accept, x1, alt)


def _spectr_rho(p, q, k):
    """k may be a traced float (effective candidate count)."""
    kf = k.astype(jnp.float32) if hasattr(k, "astype") else jnp.asarray(float(k))

    def beta(rho):
        return jnp.sum(jnp.minimum(p / rho, q))

    def g(rho):
        b = beta(rho)
        return (1.0 - (1.0 - b) ** kf) - rho * b

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        gt = g(mid) > 0
        return jnp.where(gt, mid, lo), jnp.where(gt, hi, mid)

    lo, hi = jax.lax.fori_loop(0, 60, body, (jnp.asarray(1.0), jnp.maximum(kf, 1.0)))
    rho = 0.5 * (lo + hi)
    rho = jnp.where(g(1.0) <= 0, 1.0, rho)
    rho = jnp.where(g(jnp.maximum(kf, 1.0)) >= 0, jnp.maximum(kf, 1.0), rho)
    return rho


def solve_spectr(p, q, xs, valid, key):
    kmax = xs.shape[0]
    k_eff = jnp.sum(valid.astype(jnp.float32))
    rho = _spectr_rho(p, q, jnp.maximum(k_eff, 1.0))
    cap = jnp.minimum(p / rho, q)
    beta = jnp.sum(cap)
    p_acc = 1.0 - (1.0 - beta) ** jnp.maximum(k_eff, 1.0)
    gamma = jnp.where(beta > 0, p_acc / jnp.maximum(beta, _EPS), 0.0)
    res = _norm(_pos(p - cap * gamma))
    keys = jax.random.split(key, kmax + 1)
    a = jnp.minimum(1.0, p[xs] / (rho * jnp.maximum(q[xs], _EPS)))  # (kmax,)
    a = jnp.where(valid, a, 0.0)  # padded slots never accept
    u = jax.vmap(jax.random.uniform)(keys[:kmax])
    accepts = u <= a
    first = jnp.argmax(accepts)  # first True (0 if none — guard below)
    any_acc = jnp.any(accepts)
    alt = sample_categorical(keys[kmax], res).astype(jnp.int32)
    return jnp.where(any_acc, xs[first], alt)


def solve_specinfer(p, q, xs, valid, key):
    k = xs.shape[0]

    def cond(state):
        _, mask, _, done, _ = state
        return jnp.logical_and(jnp.any(mask), jnp.logical_not(done))

    def body(state):
        pcur, mask, key, done, out = state
        key, k1, k2 = jax.random.split(key, 3)
        # uniform choice among remaining slots
        wts = mask.astype(jnp.float32)
        idx = sample_categorical(k1, wts / jnp.sum(wts))
        x = xs[idx]
        a = jnp.minimum(1.0, pcur[x] / jnp.maximum(q[x], _EPS))
        accept = jax.random.uniform(k2) <= a
        out = jnp.where(accept, x.astype(jnp.int32), out)
        done = accept
        pcur = jnp.where(accept, pcur, _norm(_pos(pcur - q)))
        mask = mask.at[idx].set(False)
        return pcur, mask, key, done, out

    key, kfin = jax.random.split(key)
    pfin, mask, key, done, out = jax.lax.while_loop(
        cond, body, (_norm(p), valid, key, jnp.asarray(False), jnp.asarray(-1, jnp.int32))
    )
    alt = sample_categorical(kfin, _norm(pfin)).astype(jnp.int32)
    return jnp.where(done, out, alt)


def khisti_importance(p, q, k):
    kf = k.astype(jnp.float32) if hasattr(k, "astype") else jnp.asarray(float(k))
    u = 1.0 - (1.0 - q) ** kf
    r = jnp.minimum(p, u)
    deficit = 1.0 - jnp.sum(r)
    head = u - r
    hs = jnp.sum(head)
    r = jnp.where(
        jnp.logical_and(deficit > 1e-12, hs > 0), r + deficit * head / jnp.maximum(hs, _EPS), r
    )
    return _norm(r)


def solve_khisti(p, q, xs, valid, key):
    k_eff = jnp.maximum(jnp.sum(valid.astype(jnp.float32)), 1.0)
    r = khisti_importance(p, q, k_eff)
    k1, k2, k3 = jax.random.split(key, 3)
    x = solve_spectr(r, q, xs, valid, k1)
    a = jnp.minimum(1.0, p[x] / jnp.maximum(r[x], _EPS))
    accept = jax.random.uniform(k2) <= a
    alt = sample_categorical(k3, _norm(_pos(p - r))).astype(jnp.int32)
    return jnp.where(accept, x, alt)


SOLVERS_JAX = {
    "nss": solve_nss,
    "naive": solve_naive,
    "naivetree": solve_naive,
    "spectr": solve_spectr,
    "specinfer": solve_specinfer,
    "khisti": solve_khisti,
}


# ------------------------------------------------- on-device tree verify -----


@partial(jax.jit, static_argnames=("solver", "max_depth", "max_children"))
def verify_topdown_jax(
    tokens: jax.Array,   # (N,) int32, node 0 = root (token ignored)
    parent: jax.Array,   # (N,) int32, -1 for root / padding
    p: jax.Array,        # (N, V) target dists per node
    q: jax.Array,        # (N, V) draft dists per node
    key: jax.Array,
    *,
    solver: str = "specinfer",
    max_depth: int = 16,
    max_children: int = 4,
):
    """Whole-tree top-down OT verification as one jitted program.

    Returns (accepted (max_depth,) int32 padded with -1, n_accepted, corr).
    Duplicate drafted nodes (merged contexts) are handled with the active-set
    mask exactly like the host implementation.
    """
    solve = SOLVERS_JAX[solver]
    N, V = p.shape

    def step(state):
        active, depth, done, out_tok, n_acc, key = state
        # children of the active set
        is_child = active[parent] & (parent >= 0)  # (N,)
        node = jnp.argmax(active)  # representative (all share context)
        # child token multiset, padded to max_children
        order = jnp.argsort(~is_child)  # children first
        child_nodes = order[:max_children]
        child_valid = is_child[child_nodes]
        xs = jnp.where(child_valid, tokens[child_nodes], -1)
        n_child = jnp.sum(is_child)
        key, k1, k2 = jax.random.split(key, 3)
        # pad xs by repeating the first child (solvers are exchangeable over
        # iid draws; padding must not add fake candidates -> clamp count by
        # masking acceptance: we instead re-sample with the true multiset by
        # selecting only valid entries (invalid get prob-0 tokens).
        xs_safe = jnp.where(xs >= 0, xs, 0)
        y = solve(p[node], q[node], xs_safe, child_valid, k1)
        # leaf: emit correction from p
        corr_leaf = sample_categorical(k2, _norm(p[node])).astype(jnp.int32)
        is_leaf = n_child == 0
        y = jnp.where(is_leaf, corr_leaf, y)
        matches = is_child & (tokens == y)
        advance = jnp.logical_and(jnp.any(matches), jnp.logical_not(is_leaf))
        out_tok = out_tok.at[depth].set(jnp.where(advance, y, -1))
        corr = jnp.where(advance, -1, y)
        n_acc = n_acc + advance.astype(jnp.int32)
        return matches, depth + 1, jnp.logical_not(advance), out_tok, n_acc, key, corr

    # unrolled fixed-depth loop with early-exit masking (max_depth is small)
    active = jnp.zeros((N,), bool).at[0].set(True)
    out_tok = jnp.full((max_depth,), -1, jnp.int32)
    done = jnp.asarray(False)
    n_acc = jnp.asarray(0, jnp.int32)
    corr = jnp.asarray(-1, jnp.int32)
    depth = jnp.asarray(0, jnp.int32)
    for _ in range(max_depth):
        new = step((active, depth, done, out_tok, n_acc, key))
        active2, depth2, done2, out2, nacc2, key2, corr2 = new
        keep = jnp.logical_not(done)
        active = jnp.where(keep, active2, active)
        out_tok = jnp.where(keep, out2, out_tok)
        n_acc = jnp.where(keep, nacc2, n_acc)
        corr = jnp.where(keep, corr2, corr)
        depth = jnp.where(keep, depth2, depth)
        key = key2
        done = jnp.logical_or(done, done2)
    return out_tok, n_acc, corr


def verify_topdown_batched(tokens, parent, p, q, keys, *, solver="specinfer",
                           max_depth=16, max_children=4):
    """vmap over a batch of trees (lockstep serving)."""
    fn = partial(verify_topdown_jax, solver=solver, max_depth=max_depth,
                 max_children=max_children)
    return jax.vmap(fn)(tokens, parent, p, q, keys)
