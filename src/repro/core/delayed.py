"""Delayed tree expansion (Sec. 5): drafting policy + block-efficiency
estimation via branching probabilities (Def. 5.3, Eq. 3).

For an OT-based verifier, conditioned on a drafted tree T:

    E[tau + 1 | T] = sum_{c' in T} P(solver reaches c' | T)
                   = sum_{paths} prod_j B(f, ch(...), t_j)            (Eq. 3)

computed exactly from the solver's branching probabilities.  The outer
expectation over trees is estimated with ``s`` i.i.d. delayed-tree samples
(the paper uses s = 4): unbiased, and free of verification variance.
"""
from __future__ import annotations

import numpy as np

from repro.core.otlp import OTLP_SOLVERS
from repro.core.trees import DraftTree, attach_target, build_delayed_tree


def expected_block_efficiency(tree: DraftTree, solver: str) -> float:
    """Eq. 3 inner sum: exact E[tau + 1 | tree] for an OT-based verifier."""
    assert tree.p is not None
    _, output_dist, _ = OTLP_SOLVERS[solver]

    total = 0.0

    def rec(active: list[int], reach: float):
        nonlocal total
        total += reach  # counts this context (root contributes the +1)
        kids = tree.children_of_set(active)
        if not kids:
            return
        node = active[0]
        d = output_dist(tree.p[node], tree.q[node], [int(tree.tokens[c]) for c in kids])
        for t in {int(tree.tokens[c]) for c in kids}:
            b = float(d[t])
            if b > 0:
                rec([c for c in kids if int(tree.tokens[c]) == t], reach * b)

    rec([0], 1.0)
    return total


def expected_block_efficiency_dist(tree: DraftTree, verifier: str) -> float:
    """E[tau + 1 | tree] for ANY registered verifier, from its exact
    conditional block law (core/verify.py registry).  The OT family also has
    the cheaper Eq. 3 recursion above; this is the generic path."""
    from repro.core.verify import get_verifier

    d = get_verifier(verifier).output_dist(tree)
    return sum(len(blk) * m for blk, m in d.items())


def expected_block_efficiency_traversal(tree: DraftTree) -> float:
    """E[tau + 1 | tree] for Traversal (from its exact conditional law)."""
    return expected_block_efficiency_dist(tree, "traversal")


def estimate_block_efficiency(
    rng: np.random.Generator,
    q_fn,
    p_fn,
    solver: str,
    K: int,
    L1: int,
    L2: int,
    context: tuple = (),
    s: int = 4,
) -> float:
    """Outer expectation of Eq. 3 over ``s`` i.i.d. delayed-tree samples.

    ``solver`` is any registered verifier name: the OT family goes through
    the Eq. 3 branching recursion, everything else through its exact
    conditional block law — so selector oracles (analytic_best_action, NDE
    labelling) work for the whole verifier zoo."""
    from repro.core.verify import get_verifier

    spec = get_verifier(solver)
    vals = []
    for _ in range(s):
        tree = build_delayed_tree(rng, q_fn, K, L1, L2, root_context=context)
        attach_target(tree, p_fn, root_context=context)
        if spec.on_device:  # top-down OT: exact Eq. 3 branching recursion
            vals.append(expected_block_efficiency(tree, solver))
        else:
            vals.append(expected_block_efficiency_dist(tree, solver))
    return float(np.mean(vals))


# ------------------------------------------------- Fig. 1 style analysis -----


def acceptance_by_depth(tree: DraftTree, solver: str, k: int) -> list[tuple[int, float]]:
    """Per-node (depth, acceptance rate alpha(f_{p,q,k})) — Def. 5.1."""
    assert tree.p is not None
    _, _, acc = OTLP_SOLVERS[solver]
    out = []
    for i in range(tree.n_nodes):
        out.append((int(tree.depth[i]), acc(tree.p[i], tree.q[i], k)))
    return out


def l1_by_depth(tree: DraftTree) -> list[tuple[int, float]]:
    """Per-node (depth, ||p - q||_1) — the divergence signal of Fig. 1."""
    assert tree.p is not None
    return [
        (int(tree.depth[i]), float(np.abs(tree.p[i] - tree.q[i]).sum()))
        for i in range(tree.n_nodes)
    ]


# ------------------------------------------ latency model (Eq. 11, App. E) ---


class LatencyModel:
    """Wall-clock model of draft/target forward passes.

    t_q(l), t_p(l): seconds for a forward pass at context length l.  On real
    hardware these come from a warm-up microbenchmark; here they are derived
    from the TPU roofline terms of the compiled dry-run (see DESIGN.md) or
    set synthetically in tests.  The affine form a + b*l captures the
    memory-bound decode regime (weights read + KV read).
    """

    def __init__(self, t_q_base: float, t_q_per_tok: float, t_p_base: float, t_p_per_tok: float,
                 t_p_per_tree_tok: float = 0.0):
        self.t_q_base = t_q_base
        self.t_q_per_tok = t_q_per_tok
        self.t_p_base = t_p_base
        self.t_p_per_tok = t_p_per_tok
        # marginal cost of one extra speculation token in the batched target
        # pass.  Eq. 11 as printed prices the tree only through the context-
        # length term, making 32-node trees nearly free; the measured tree
        # economics (benchmarks/tree_economics.py: qwen2-72b, +66% step time
        # at T=32) give ~2% of t_p_base per tree token on TPU v5e.
        self.t_p_per_tree_tok = t_p_per_tree_tok

    def t_q(self, l) -> float:
        return self.t_q_base + self.t_q_per_tok * float(l)

    def t_p(self, l) -> float:
        return self.t_p_base + self.t_p_per_tok * float(l)

    def action_time(self, ctx_len: int, K: int, L1: int, L2: int) -> float:
        """Eq. 11: trunk drafting + branch drafting + one target tree pass."""
        t = 0.0
        for j in range(L1):
            t += self.t_q(ctx_len + j)
        for j in range(L2):
            t += self.t_q(ctx_len + L1 + j * K)
        t += self.t_p(ctx_len + L1 + K * L2)
        t += self.t_p_per_tree_tok * (L1 + K * L2)
        return t


def analytic_best_action(
    rng: np.random.Generator,
    q_fn,
    p_fn,
    solver: str,
    latency: LatencyModel,
    ctx: tuple,
    K_max: int = 4,
    L1_max: int = 8,
    L2_max: int = 8,
    s: int = 1,
    actions=None,
) -> tuple:
    """Beyond-paper: exhaustively maximise Ê[tau+1]/T̂ over the action space
    using the exact Eq. 3 estimator (the paper instead trains an MLP on
    offline traces; this oracle is also used to label its training data)."""
    best, best_tps = None, -1.0
    if actions is None:
        actions = [
            (K, L1, L2)
            for K in range(1, K_max + 1)
            for L1 in range(L1_max + 1)
            for L2 in range(L2_max + 1)
            if L1 + L2 > 0 and not (K > 1 and L2 == 0)
        ]
    for K, L1, L2 in actions:
        be = estimate_block_efficiency(rng, q_fn, p_fn, solver, K, L1, L2, context=ctx, s=s)
        tps = be / latency.action_time(len(ctx), K, L1, L2)
        if tps > best_tps:
            best, best_tps = (K, L1, L2), tps
    return best, best_tps
