"""UniVer — unified multi-step x multi-draft verification (arXiv 2605.04543).

The retrieval pins UniVer down only by its properties (one verifier unifying
Block Verification's multi-step nested-weight coupling with SpecInfer's
multi-draft OT coupling; reduces to BV at K=1 and to SpecInfer at L1=0,
L2=1), so — as with traversal.py — the scheme is derived from first
principles and proven lossless by exact enumeration (tests/test_lossless.py).

Construction: walk the tree top-down over active sets (merged-context
multiset semantics, Def. 3.1).  At each point the child multiset of the
active set picks the coupling:

* multiset size >= 2 — one SpecInfer OT step on (p, q, child tokens): the
  residual-corrected multi-draft coupling emits either a drafted child
  (recurse into its match set) or a correction token (the block ends).
* multiset size == 1 — a *segment*: the maximal unary chain ahead is
  verified as one BV block with nested weights  w_0 = 1,
  w_i = min(1, w_{i-1} p_i(x_i)/q_i(x_i))  and the conditional leaf-to-root
  climb of traversal.py.  Accepting depth i < L emits the chain prefix plus
  a correction ~ norm((w_i p_{i+1} - q_{i+1})_+); full rejection emits
  norm((p_1 - q_1)_+); full acceptance *continues the walk* at the segment
  end — the next stage replaces BV's terminal p_{L+1} sample.
* empty multiset — leaf: emit a fresh target sample and stop.

Losslessness: each stage is a lossless block coupling given its reach event,
and a stage's randomness is independent of deeper draft draws, so the
composite is lossless by the induction of core/enumerate.py (a lossless
continuation contributes to the G-criterion exactly like a target sample
followed by target continuation).  On a delayed (K, L1, L2) tree the trunk
is one segment, the branch root is a SpecInfer step over the K branch heads,
and surviving match sets decay into segments — hence both reductions hold
by construction.
"""
from __future__ import annotations

import numpy as np

from repro.core.otlp import OTLP_SOLVERS, _norm, _pos
from repro.core.traversal import _EPS, _climb_masses, _pq, _segment_correction, _tok, _trunk_weights
from repro.core.trees import DraftTree


def _segment(tree: DraftTree, active: list[int]) -> list[int]:
    """Maximal unary chain ahead of ``active`` (levels whose child multiset
    has exactly one element)."""
    seg: list[int] = []
    a = list(active)
    while True:
        kids = tree.children_of_set(a)
        if len(kids) != 1:
            return seg
        seg.append(kids[0])
        a = kids


def verify_univer(tree: DraftTree, rng: np.random.Generator):
    """Sample the UniVer verifier.  Returns (accepted_tokens, correction)."""
    assert tree.p is not None, "attach_target first"
    solve, _, _ = OTLP_SOLVERS["specinfer"]
    active = [0]
    accepted: list[int] = []
    while True:
        kids = tree.children_of_set(active)
        node = active[0]
        p, q = _pq(tree, node)
        if not kids:  # leaf: fresh target sample
            return accepted, int(rng.choice(tree.vocab, p=_norm(p)))
        if len(kids) >= 2:  # SpecInfer OT step on the child multiset
            xs = [_tok(tree, c) for c in kids]
            y = int(solve(p, q, xs, rng))
            matches = [c for c in kids if _tok(tree, c) == y]
            if not matches:
                return accepted, y
            accepted.append(y)
            active = matches
            continue
        # BV segment over the maximal unary chain
        seg = _segment(tree, active)
        vs = _trunk_weights(tree, seg)
        masses, surv = _climb_masses(tree, seg, vs)
        u = rng.random()
        csum, tau = 0.0, 0
        for j in range(len(seg), 0, -1):
            csum += masses[j - 1]
            if u < csum:
                tau = j
                break
        if tau == len(seg):  # full acceptance: continue at the segment end
            accepted.extend(_tok(tree, v) for v in seg)
            active = [seg[-1]]
            continue
        if tau:
            accepted.extend(_tok(tree, v) for v in seg[:tau])
            corr = int(rng.choice(tree.vocab, p=_segment_correction(tree, seg, vs, tau)))
            return accepted, corr
        resid = _pos(p - q)
        if resid.sum() <= _EPS:  # p == q: full rejection has measure zero
            resid = p
        return accepted, int(rng.choice(tree.vocab, p=_norm(resid)))


def univer_output_dist(tree: DraftTree) -> dict:
    """Exact emitted-block distribution of UniVer conditioned on the tree."""
    assert tree.p is not None
    _, specinfer_dist, _ = OTLP_SOLVERS["specinfer"]
    out: dict = {}

    def add(prefix: tuple, dist, mass: float):
        if mass <= 0:
            return
        for t, pt in enumerate(dist):
            if pt > 0:
                key = prefix + (t,)
                out[key] = out.get(key, 0.0) + mass * float(pt)

    def rec(active: list[int], prefix: tuple, mass: float):
        if mass <= 0:
            return
        kids = tree.children_of_set(active)
        node = active[0]
        p, q = _pq(tree, node)
        if not kids:
            add(prefix, _norm(p), mass)
            return
        if len(kids) >= 2:
            xs = [_tok(tree, c) for c in kids]
            d = specinfer_dist(p, q, xs)
            xs_set = set(xs)
            for t, dt in enumerate(d):
                if dt <= 0:
                    continue
                if t in xs_set:
                    rec([c for c in kids if _tok(tree, c) == t], prefix + (t,), mass * float(dt))
                else:
                    key = prefix + (t,)
                    out[key] = out.get(key, 0.0) + mass * float(dt)
            return
        seg = _segment(tree, active)
        vs = _trunk_weights(tree, seg)
        masses, surv = _climb_masses(tree, seg, vs)
        toks = tuple(_tok(tree, v) for v in seg)
        for j in range(1, len(seg)):
            add(prefix + toks[:j], _segment_correction(tree, seg, vs, j), mass * float(masses[j - 1]))
        rec([seg[-1]], prefix + toks, mass * float(masses[-1]))
        if surv > 0:
            resid = _pos(p - q)
            if resid.sum() > _EPS:
                add(prefix, _norm(resid), mass * float(surv))

    rec([0], (), 1.0)
    return out
