"""Core library: the paper's contribution.

- otlp:       OTLP solvers (Def. 3.2, App. B) + acceptance (App. C) +
              exact output distributions / branching probabilities (App. D)
- trees:      draft-tree structures, delayed-tree drafting (Def. 5.2)
- verify:     top-down OT tree traversal; single-path Naive
- traversal:  bottom-up Traversal Verification (+ BV as its K=1 reduction)
- delayed:    Eq. 3 block-efficiency estimation, Eq. 11 latency model,
              Fig. 1 acceptance/divergence analysis
- selector:   the neural delay-and-branch predictor (Sec. 6, App. E)
"""
from repro.core import delayed, otlp, traversal, trees, verify  # noqa: F401
