"""Exact enumeration machinery for losslessness verification.

Losslessness of one verify round: let D(block) be the distribution of the
emitted block (accepted path + correction token).  Future rounds continue
from the block's end with exact target conditionals (by induction), so the
overall process is target-distributed iff for every string y_{1:n}:

    G(y_{1:n}) :=  sum_{m < n} D(y_{1:m}) * prod_{i=m+1..n} p(y_i|y_{<i})
                 + P(block has prefix y_{1:n})
                =  prod_{i=1..n} p(y_i|y_{<i})

We verify this for all strings up to a given length by enumerating *both*
draft-tree randomness and verifier randomness exactly.
"""
from __future__ import annotations

import itertools
import zlib

import numpy as np

from repro.core.trees import DraftTree, attach_target


class RandomModel:
    """Deterministic random (p, q) tables per context; small vocab."""

    def __init__(self, vocab: int, seed: int = 0, divergence: float = 1.0, zeros: bool = False):
        self.vocab = vocab
        self.seed = seed
        self.divergence = divergence
        self.zeros = zeros
        self._cache: dict = {}

    def _dists(self, ctx: tuple):
        if ctx not in self._cache:
            rng = np.random.default_rng(zlib.crc32(repr(("m", self.seed, ctx)).encode()))
            p = rng.dirichlet(np.ones(self.vocab))
            noise = rng.dirichlet(np.ones(self.vocab))
            q = (1 - self.divergence) * p + self.divergence * noise
            if self.zeros and self.vocab >= 3:
                # exercise disjoint-support edge cases
                p = p.copy()
                q = q.copy()
                p[rng.integers(self.vocab)] = 0.0
                q[rng.integers(self.vocab)] = 0.0
                p = p / p.sum()
                q = q / q.sum()
            self._cache[ctx] = (p, q)
        return self._cache[ctx]

    def p(self, ctx):
        return self._dists(tuple(ctx))[0]

    def q(self, ctx):
        return self._dists(tuple(ctx))[1]


def build_tree_from_draws(model: RandomModel, K: int, L1: int, L2: int, draws: tuple) -> tuple:
    """Build a delayed tree from explicit token draws; returns (tree, prob)."""
    tokens = [-1]
    parent = [-1]
    depth = [0]
    pid = [0]
    qs = [model.q(())]
    prob = 1.0
    it = iter(draws)
    ctx: tuple = ()
    node = 0
    for _ in range(L1):
        t = next(it)
        prob *= float(qs[node][t])
        ctx = ctx + (t,)
        tokens.append(t)
        parent.append(node)
        depth.append(depth[node] + 1)
        pid.append(0)
        qs.append(model.q(ctx))
        node = len(tokens) - 1
    bnode, bctx = node, ctx
    for k in range(K):
        node, ctx = bnode, bctx
        for _ in range(L2):
            t = next(it)
            prob *= float(qs[node][t])
            ctx = ctx + (t,)
            tokens.append(t)
            parent.append(node)
            depth.append(depth[node] + 1)
            pid.append(k)
            qs.append(model.q(ctx))
            node = len(tokens) - 1
    tree = DraftTree(
        tokens=np.asarray(tokens, dtype=np.int64),
        parent=np.asarray(parent, dtype=np.int64),
        depth=np.asarray(depth, dtype=np.int64),
        q=np.stack(qs),
        path_id=np.asarray(pid, dtype=np.int64),
    )
    attach_target(tree, model.p)
    return tree, prob


def iter_trees(model: RandomModel, K: int, L1: int, L2: int):
    n_draws = L1 + K * L2
    for draws in itertools.product(range(model.vocab), repeat=n_draws):
        tree, prob = build_tree_from_draws(model, K, L1, L2, draws)
        if prob > 0:
            yield tree, prob


def expected_block_dist(dist_fn, model: RandomModel, K: int, L1: int, L2: int) -> dict:
    """E over trees of the verifier's exact conditional block distribution."""
    agg: dict = {}
    for tree, prob in iter_trees(model, K, L1, L2):
        d = dist_fn(tree)
        for blk, m in d.items():
            agg[blk] = agg.get(blk, 0.0) + prob * m
    return agg


def lossless_gap(block_dist: dict, model: RandomModel, max_len: int) -> float:
    """Max |G(y) - P_target(y)| over all strings up to max_len."""

    def target_prob(y):
        pr = 1.0
        for i, t in enumerate(y):
            pr *= float(model.p(y[:i])[t])
        return pr

    worst = 0.0
    for n in range(1, max_len + 1):
        for y in itertools.product(range(model.vocab), repeat=n):
            g = 0.0
            # blocks that are strict prefixes of y, extended by target
            for m in range(1, n):
                blk = y[:m]
                if blk in block_dist:
                    ext = 1.0
                    for i in range(m, n):
                        ext *= float(model.p(y[:i])[y[i]])
                    g += block_dist[blk] * ext
            # blocks that contain y as a prefix
            for blk, mass in block_dist.items():
                if len(blk) >= n and blk[:n] == y:
                    g += mass
            worst = max(worst, abs(g - target_prob(y)))
    return worst


def mean_block_len(block_dist: dict) -> float:
    return sum(len(b) * m for b, m in block_dist.items())
