"""Greedy Multi-Path Block Verification (arXiv 2602.16961).

The retrieval pins the scheme down only by its properties (greedy path
selection with BV-style nested weights per path; multi-path generalisation
of Block Verification; lossless), so — as with traversal.py — it is derived
from first principles and proven lossless by exact enumeration
(tests/test_lossless.py).

Greedy selection.  Walk the tree level by level, always descending into the
drafted child token with the highest target/draft ratio p(t)/q(t) (ties:
smaller token id).  The m multiset children at a level are i.i.d. draft
draws, so the selected token's conditional law has the closed form

    g(t) = W_t^m - (W_t - q(t))^m,
    W_t  = sum of q(s) over tokens s not strictly better than t,

the max-order-statistic law of the greedy rule under the strict total order
(ratio, -token).  The greedily-selected path is therefore a draw from a
*known adapted proposal process* with per-step conditionals g_i — and
single-path Block Verification applies verbatim with q_i replaced by g_i:

    w_0 = 1,  w_i = min(1, w_{i-1} p_i(t_i) / g_i(t_i)),

realised through the conditional leaf-to-root climb of traversal.py
(e_{i+1} = sum_s min(w_i p(s), g_{i+1}(s))), with corrections

    depth i < L:  norm((w_i p_{i+1} - g_{i+1})_+)
    depth L:      p(.|leaf)            root:  norm((p_1 - g_1)_+).

Adaptedness is what makes the greedy order sound: the multiset size m_i is
a function of shallower draws only, and conditional on it the level's draws
are fresh i.i.d. q — so g_i is exactly the conditional law of the winner
given everything the verifier has used so far.  (A greedy order with the
*unadjusted* q-ratios is provably biased: for p=(.6,.4), q=(.5,.5), K=2 it
emits token 0 with probability .75 instead of .6.)

At K=1 every level has m=1, g == q, and the scheme is exactly Block
Verification; at L1=0, L2=1 it is the greedy one-step multi-draft coupling.
"""
from __future__ import annotations

import numpy as np

from repro.core.otlp import _norm, _pos
from repro.core.traversal import _EPS, _pq, _tok
from repro.core.trees import DraftTree


def _winner_law(p: np.ndarray, q: np.ndarray, xs: list[int]):
    """Greedy winner of the drafted multiset ``xs`` (m i.i.d. q-draws) and
    the exact law of that winner over the vocab."""
    m = len(xs)
    ratio = np.where(q > 0, p / np.maximum(q, _EPS), -np.inf)
    order = sorted(np.flatnonzero(q > 0).tolist(), key=lambda t: (ratio[t], -t))
    g = np.zeros_like(q)
    w_cum = 0.0
    for t in order:  # ascending: worst token first
        w_cum += float(q[t])
        g[t] = w_cum**m - (w_cum - float(q[t])) ** m
    t_star = max(set(xs), key=lambda t: (ratio[t], -t))
    return int(t_star), g


def _greedy_chain(tree: DraftTree):
    """Deterministic greedy walk.  Returns (nodes, gs, ws): representative
    winner node, winner law, and nested weight per level."""
    active = [0]
    nodes: list[int] = []
    gs: list[np.ndarray] = []
    ws: list[float] = []
    w = 1.0
    while True:
        kids = tree.children_of_set(active)
        if not kids:
            return nodes, gs, ws
        node = active[0]
        p, q = _pq(tree, node)
        xs = [_tok(tree, c) for c in kids]
        t_star, g = _winner_law(p, q, xs)
        w = min(1.0, w * float(p[t_star]) / max(float(g[t_star]), _EPS))
        nodes.append([c for c in kids if _tok(tree, c) == t_star][0])
        gs.append(g)
        ws.append(w)
        active = [c for c in kids if _tok(tree, c) == t_star]


def _greedy_climb(tree: DraftTree, nodes, gs, ws):
    """Conditional leaf-to-root climb over the greedy chain; returns
    (masses, reject_prob) exactly as traversal._climb_masses but against the
    winner laws g instead of q."""
    L = len(nodes)
    alphas = np.zeros(L)
    alphas[L - 1] = ws[L - 1]
    for j in range(L - 1, 0, -1):
        p, _ = _pq(tree, nodes[j - 1])
        e = float(np.sum(np.minimum(ws[j - 1] * p, gs[j])))
        a = (ws[j - 1] - e) / max(1.0 - e, _EPS) if e < 1.0 else 0.0
        alphas[j - 1] = min(max(a, 0.0), 1.0)
    masses = np.zeros(L)
    surv = 1.0
    for j in range(L, 0, -1):
        masses[j - 1] = surv * alphas[j - 1]
        surv *= 1.0 - alphas[j - 1]
    return masses, surv


def _greedy_correction(tree: DraftTree, nodes, gs, ws, j: int) -> np.ndarray:
    """Correction distribution on accepting depth j (1-indexed)."""
    p, _ = _pq(tree, nodes[j - 1])
    if j == len(nodes):
        return _norm(p)
    return _norm(_pos(ws[j - 1] * p - gs[j]))


def _root_correction(tree: DraftTree, gs) -> np.ndarray:
    p0, _ = _pq(tree, 0)
    resid = _pos(p0 - gs[0])
    if resid.sum() <= _EPS:  # p == g: full rejection has measure zero
        resid = p0
    return _norm(resid)


def verify_greedy_mpbv(tree: DraftTree, rng: np.random.Generator):
    """Sample the greedy multi-path BV verifier.  Returns
    (accepted_tokens, correction)."""
    assert tree.p is not None, "attach_target first"
    nodes, gs, ws = _greedy_chain(tree)
    if not nodes:
        p0, _ = _pq(tree, 0)
        return [], int(rng.choice(tree.vocab, p=_norm(p0)))
    masses, _ = _greedy_climb(tree, nodes, gs, ws)
    u = rng.random()
    csum = 0.0
    for j in range(len(nodes), 0, -1):
        csum += masses[j - 1]
        if u < csum:
            corr = int(rng.choice(tree.vocab, p=_greedy_correction(tree, nodes, gs, ws, j)))
            return tree.path_tokens(nodes[j - 1]), corr
    return [], int(rng.choice(tree.vocab, p=_root_correction(tree, gs)))


def greedy_mpbv_output_dist(tree: DraftTree) -> dict:
    """Exact emitted-block distribution conditioned on the drafted tree
    (the greedy chain is deterministic given the tree)."""
    assert tree.p is not None
    nodes, gs, ws = _greedy_chain(tree)
    out: dict = {}

    def add(prefix, dist, mass):
        if mass <= 0:
            return
        for t, pt in enumerate(dist):
            if pt > 0:
                key = tuple(prefix) + (t,)
                out[key] = out.get(key, 0.0) + mass * float(pt)

    if not nodes:
        p0, _ = _pq(tree, 0)
        add([], _norm(p0), 1.0)
        return out
    masses, surv = _greedy_climb(tree, nodes, gs, ws)
    for j in range(len(nodes), 0, -1):
        add(tree.path_tokens(nodes[j - 1]), _greedy_correction(tree, nodes, gs, ws, j),
            float(masses[j - 1]))
    if surv > 0:
        add([], _root_correction(tree, gs), float(surv))
    return out
