"""Neural delay-and-branch predictor (NDE) — Sec. 6 and Appendix E.

A lightweight MLP policy over the delayed-expansion action space
A = {1..K_max} x {0..L1_max} x {0..L2_max}.  Inputs (App. E):

  * hidden-state blocks:  h_prev^p, h_prev^q (target/draft states at the
    preceding token) and h_cur^q (draft state at the root token) — each
    linearly projected to d=128 + LayerNorm,
  * standardized scalar features: entropies H(p_prev), H(q_prev), H(q_root),
    KL(p_prev||q_prev), KL(q_prev||p_prev), ||p_prev - q_prev||_1,
    context length, temperature, nucleus threshold, and draft/target latency
    estimates at the current context length,
  * two-hidden-layer MLP (512 -> 32) with GELU + dropout -> |A| logits.

Training (Eq. 4/5/12): maximise the policy-averaged offline throughput
estimate against a static per-sampling-config baseline action, with a CVaR
penalty on the worst alpha-fraction of baseline regressions.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ActionSpace:
    K_max: int = 4
    L1_max: int = 8
    L2_max: int = 8

    def actions(self) -> list[tuple[int, int, int]]:
        # (K, L1, L2); drop degenerate duplicates: L1+L2 == 0 drafts nothing,
        # and K>1 with L2 == 0 is identical to K=1 with the same L1.
        out = []
        for K in range(1, self.K_max + 1):
            for L1 in range(self.L1_max + 1):
                for L2 in range(self.L2_max + 1):
                    if L1 + L2 == 0:
                        continue
                    if K > 1 and L2 == 0:
                        continue
                    out.append((K, L1, L2))
        return out

    @property
    def n(self) -> int:
        return len(self.actions())


class FixedSpace:
    """An explicit action grid (used when offline labels cover a subset)."""

    def __init__(self, actions: list[tuple[int, int, int]]):
        self._actions = list(actions)

    def actions(self) -> list[tuple[int, int, int]]:
        return self._actions

    @property
    def n(self) -> int:
        return len(self._actions)


@dataclass(frozen=True)
class SelectorConfig:
    hidden_p: int = 64     # dim of target hidden states fed in
    hidden_q: int = 64     # dim of draft hidden states fed in
    d_proj: int = 128
    mlp_hidden: tuple = (512, 32)
    n_scalars: int = 11
    dropout: float = 0.1
    space: ActionSpace = field(default_factory=ActionSpace)


def init_selector(cfg: SelectorConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 8)

    def dense(k, din, dout):
        return {
            "w": jax.random.normal(k, (din, dout), jnp.float32) / np.sqrt(din),
            "b": jnp.zeros((dout,), jnp.float32),
        }

    n_act = cfg.space.n
    d_in = 3 * cfg.d_proj + cfg.n_scalars
    return {
        "proj_hp": dense(ks[0], cfg.hidden_p, cfg.d_proj),
        "proj_hq": dense(ks[1], cfg.hidden_q, cfg.d_proj),
        "proj_hc": dense(ks[2], cfg.hidden_q, cfg.d_proj),
        "mlp0": dense(ks[3], d_in, cfg.mlp_hidden[0]),
        "mlp1": dense(ks[4], cfg.mlp_hidden[0], cfg.mlp_hidden[1]),
        "out": dense(ks[5], cfg.mlp_hidden[1], n_act),
    }


def _ln(x):
    m = jnp.mean(x, axis=-1, keepdims=True)
    v = jnp.var(x, axis=-1, keepdims=True)
    return (x - m) / jnp.sqrt(v + 1e-6)


def _apply_dense(layer, x):
    return x @ layer["w"] + layer["b"]


def selector_logits(
    params: dict,
    h_prev_p: jax.Array,
    h_prev_q: jax.Array,
    h_cur_q: jax.Array,
    scalars: jax.Array,
    *,
    dropout_key: jax.Array | None = None,
    dropout: float = 0.0,
) -> jax.Array:
    """Eq. 10.  Inputs may carry a leading batch axis."""
    z = jnp.concatenate(
        [
            _ln(_apply_dense(params["proj_hp"], h_prev_p)),
            _ln(_apply_dense(params["proj_hq"], h_prev_q)),
            _ln(_apply_dense(params["proj_hc"], h_cur_q)),
            scalars,
        ],
        axis=-1,
    )
    h = jax.nn.gelu(_apply_dense(params["mlp0"], z))
    if dropout_key is not None and dropout > 0:
        keep = jax.random.bernoulli(dropout_key, 1.0 - dropout, h.shape)
        h = jnp.where(keep, h / (1.0 - dropout), 0.0)
    h = jax.nn.gelu(_apply_dense(params["mlp1"], h))
    return _apply_dense(params["out"], h)


def make_scalar_features(
    p_prev: np.ndarray,
    q_prev: np.ndarray,
    q_root: np.ndarray,
    ctx_len: int,
    temperature: float,
    top_p: float,
    t_q: float,
    t_p: float,
) -> np.ndarray:
    """App. E scalar feature block (11 features, standardized by the caller
    or absorbed by the first dense layer)."""

    def H(d):
        d = np.clip(d, 1e-12, None)
        return float(-(d * np.log(d)).sum())

    def KL(a, b):
        a = np.clip(a, 1e-12, None)
        b = np.clip(b, 1e-12, None)
        return float((a * (np.log(a) - np.log(b))).sum())

    return np.asarray(
        [
            H(p_prev),
            H(q_prev),
            H(q_root),
            KL(p_prev, q_prev),
            KL(q_prev, p_prev),
            float(np.abs(p_prev - q_prev).sum()),
            np.log1p(float(ctx_len)),
            float(temperature),
            float(top_p),
            float(t_q) * 1e3,
            float(t_p) * 1e3,
        ],
        dtype=np.float32,
    )


# ------------------------------------------------------------- training ------


def selector_loss(
    params: dict,
    batch: dict,
    *,
    lam: float = 1.0,
    cvar_alpha: float = 0.25,
    aux_ce: float = 0.5,
    ce_temp: float = 0.05,
    dropout_key: jax.Array | None = None,
    dropout: float = 0.0,
) -> jax.Array:
    """Eq. 12 + optimal-action distillation.

    The primary term is the paper's baseline-relative log-throughput with the
    CVaR regression penalty.  Its gradient vanishes once the softmax
    saturates on the globally-best action, which collapses the policy to the
    static baseline; App. E describes the logits as "the probabilities of
    each action being optimal", so we add the implied auxiliary
    cross-entropy against the per-root TPS-softmax target (temperature
    ``ce_temp`` on the normalised TPS landscape) — this is what makes the
    per-context selection actually trainable on offline traces.

    batch:
      h_prev_p (B, Hp), h_prev_q (B, Hq), h_cur_q (B, Hq), scalars (B, S),
      eff   (B, A): offline block-efficiency estimates E^[tau+1] per action
      time  (B, A): Eq. 11 wall-clock estimates per action
      base  (B,)  : index of the static baseline action
    """
    logits = selector_logits(
        params,
        batch["h_prev_p"],
        batch["h_prev_q"],
        batch["h_cur_q"],
        batch["scalars"],
        dropout_key=dropout_key,
        dropout=dropout,
    )
    pi = jax.nn.softmax(logits, axis=-1)
    tps = batch["eff"] / jnp.maximum(batch["time"], 1e-9)  # (B, A)
    tps_pi = jnp.sum(pi * batch["eff"], axis=-1) / jnp.sum(pi * batch["time"], axis=-1)  # Eq. 4
    b = batch["base"]
    eff_b = jnp.take_along_axis(batch["eff"], b[:, None], axis=-1)[:, 0]
    time_b = jnp.take_along_axis(batch["time"], b[:, None], axis=-1)[:, 0]
    tps_base = eff_b / time_b
    ratio = tps_pi / jnp.maximum(tps_base, 1e-9)
    main = -jnp.log(jnp.maximum(ratio, 1e-9))  # Eq. 5
    pen = jnp.square(jnp.maximum(1.0 - ratio, 0.0))
    # CVaR over the worst alpha-fraction of the minibatch penalties
    B = pen.shape[0]
    k = max(int(np.ceil(cvar_alpha * B)), 1)
    topk = jax.lax.top_k(pen, k)[0]
    loss = jnp.mean(main) + lam * jnp.mean(topk)
    if aux_ce > 0:
        tps_n = tps / jnp.max(tps, axis=-1, keepdims=True)
        target = jax.nn.softmax(tps_n / ce_temp, axis=-1)
        ce = -jnp.sum(target * jax.nn.log_softmax(logits, axis=-1), axis=-1)
        loss = loss + aux_ce * jnp.mean(ce)
    return loss


def select_action(
    params: dict, h_prev_p, h_prev_q, h_cur_q, scalars, space: ActionSpace
) -> tuple[int, int, int]:
    """Inference: argmax_a pi(a|c)."""
    logits = selector_logits(params, h_prev_p, h_prev_q, h_cur_q, scalars)
    idx = int(jnp.argmax(logits.reshape(-1)))
    return space.actions()[idx]
