"""Offline NDE selector training (Sec. 6.1 / App. E).

Pipeline:
  1. collect_traces: run the engine along target trajectories, taking a root
     every ``stride`` tokens; at each root, estimate E^[tau+1] for every
     action on the grid with the Eq. 3 estimator (s i.i.d. delayed trees)
     against the *real* draft/target, and T^ with the Eq. 11 latency model.
  2. train_selector: minimise the Eq. 12 objective with AdamW.

The static baseline action per sampling configuration follows the paper: the
best fixed (K, L1, L2) on the trace set for that (temperature, top_p).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.delayed import LatencyModel, estimate_block_efficiency
from repro.core.selector import (
    SelectorConfig,
    init_selector,
    make_scalar_features,
    selector_loss,
)
from repro.training.optim import AdamW


def collect_traces(
    engine,
    prompts: list[list[int]],
    actions: list[tuple],
    latency: LatencyModel,
    *,
    tokens_per_prompt: int = 32,
    stride: int = 8,
    s: int = 2,
    seed: int = 0,
) -> dict:
    """Returns arrays: h_prev_p, h_prev_q, h_cur_q, scalars, eff, time."""
    rng = np.random.default_rng(seed)
    rows = {k: [] for k in ["h_prev_p", "h_prev_q", "h_cur_q", "scalars", "eff", "time"]}
    for prompt in prompts:
        stream = engine.new_stream(list(prompt))
        produced = 0
        since_root = stride  # take the first root immediately
        while produced < tokens_per_prompt:
            if since_root >= stride:
                since_root = 0
                # ---- label one root ----
                def q_fn(ctx):
                    return engine.peek_draft_dist(stream, list(ctx))

                def p_fn(ctx):
                    return engine.peek_target_dist(stream, list(ctx))

                l = len(stream["committed"])
                effs, times = [], []
                for (K, L1, L2) in actions:
                    effs.append(
                        estimate_block_efficiency(rng, q_fn, p_fn, engine.ecfg.verifier, K, L1, L2, s=s)
                    )
                    times.append(latency.action_time(l, K, L1, L2))
                V = engine.tc.vocab
                p_prev = stream["p_prev"] if stream["p_prev"] is not None else np.full(V, 1 / V)
                q_prev = stream["q_prev"] if stream["q_prev"] is not None else np.full(V, 1 / V)
                q_root = engine.peek_draft_dist(stream, [])
                rows["h_prev_p"].append(np.asarray(stream["h_prev_p"], np.float32))
                rows["h_prev_q"].append(np.asarray(stream["h_prev_q"], np.float32))
                rows["h_cur_q"].append(np.asarray(stream["h_prev_q"], np.float32))
                rows["scalars"].append(
                    make_scalar_features(
                        p_prev, q_prev, q_root, l,
                        engine.sampling.temperature, engine.sampling.top_p,
                        latency.t_q(l), latency.t_p(l),
                    )
                )
                rows["eff"].append(np.asarray(effs, np.float32))
                rows["time"].append(np.asarray(times, np.float32))
            new = engine.step(stream)
            produced += len(new)
            since_root += len(new)
    return {k: np.stack(v) for k, v in rows.items()}


def best_static_action(traces: dict) -> int:
    """Index of the fixed action with the best average offline throughput."""
    tps = traces["eff"] / traces["time"]
    return int(np.argmax(tps.mean(axis=0)))


def train_selector(
    traces: dict,
    scfg: SelectorConfig,
    *,
    steps: int = 300,
    batch: int = 32,
    lr: float = 1e-3,
    lam: float = 1.0,
    cvar_alpha: float = 0.25,
    aux_ce: float = 0.5,
    seed: int = 0,
    base_idx: int | None = None,
):
    key = jax.random.PRNGKey(seed)
    params = init_selector(scfg, key)
    opt = AdamW(lr=lr, total_steps=steps, warmup_steps=max(steps // 20, 1))
    state = opt.init(params)
    n = traces["eff"].shape[0]
    if base_idx is None:
        base_idx = best_static_action(traces)
    base = np.full(n, base_idx, np.int32)
    data = {
        "h_prev_p": jnp.asarray(traces["h_prev_p"]),
        "h_prev_q": jnp.asarray(traces["h_prev_q"]),
        "h_cur_q": jnp.asarray(traces["h_cur_q"]),
        "scalars": jnp.asarray(_standardize(traces["scalars"])),
        "eff": jnp.asarray(traces["eff"]),
        "time": jnp.asarray(traces["time"]),
        "base": jnp.asarray(base),
    }

    @jax.jit
    def step_fn(params, state, idx, key):
        batch_d = {k: v[idx] for k, v in data.items()}
        loss, grads = jax.value_and_grad(
            lambda p: selector_loss(p, batch_d, lam=lam, cvar_alpha=cvar_alpha,
                                    aux_ce=aux_ce, dropout_key=key, dropout=scfg.dropout)
        )(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    rng = np.random.default_rng(seed)
    losses = []
    for i in range(steps):
        idx = jnp.asarray(rng.integers(0, n, size=min(batch, n)))
        key, sub = jax.random.split(key)
        params, state, loss = step_fn(params, state, idx, sub)
        losses.append(float(loss))
    return params, losses


def _standardize(x: np.ndarray) -> np.ndarray:
    mu = x.mean(axis=0, keepdims=True)
    sd = x.std(axis=0, keepdims=True) + 1e-6
    return (x - mu) / sd
