"""Generic training loop: jit'd train_step + logging + checkpointing.

Used by launch/train.py (distributed via jit in/out shardings installed by
the caller) and by the end-to-end example (single host).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.models.transformer import init_params, make_train_step
from repro.training.checkpoint import save_checkpoint
from repro.training.optim import AdamW


def train(
    cfg,
    data_iter,
    *,
    steps: int = 100,
    lr: float = 3e-4,
    seed: int = 0,
    log_every: int = 10,
    ckpt_path: str | None = None,
    ckpt_every: int = 0,
    train_step=None,
    params=None,
    opt=None,
    log_fn=print,
):
    opt = opt or AdamW(lr=lr, total_steps=steps, warmup_steps=max(steps // 20, 1))
    params = params if params is not None else init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    step_fn = train_step or jax.jit(make_train_step(cfg, opt))
    losses = []
    t0 = time.time()
    for i in range(steps):
        batch = next(data_iter)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if (i + 1) % log_every == 0 or i == 0:
            l = float(loss)
            losses.append((i + 1, l))
            dt = time.time() - t0
            tok = np.prod(batch["tokens"].shape)
            log_fn(f"step {i+1:5d}  loss {l:.4f}  {tok * (i + 1) / dt:.0f} tok/s")
        if ckpt_path and ckpt_every and (i + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_path, params, step=i + 1)
    if ckpt_path:
        save_checkpoint(ckpt_path, params, step=steps)
    return params, losses
