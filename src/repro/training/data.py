"""Token data pipeline.

Two sources:
  * SyntheticLM — a fixed random-parameter bigram/skip-gram process with
    enough structure that a ~100M model measurably learns it (used by the
    end-to-end training example and the smoke tests; no external data in
    this container).
  * MemmapDataset — standard packed-token binary (np.uint16/uint32 memmap),
    the production path for real corpora.

Both yield dict batches {"tokens": (B, S), "labels": (B, S)} with labels
shifted left and the final position masked (-1).
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    """Structured synthetic language: a hidden 2nd-order Markov chain over
    ``vocab`` tokens with sparse transitions + occasional copy spans."""

    def __init__(self, vocab: int, seed: int = 0, branch: int = 8):
        self.vocab = vocab
        rng = np.random.default_rng(seed)
        self.branch = branch
        # each (prev2 hash) selects `branch` candidate next tokens
        self.table = rng.integers(0, vocab, size=(4096, branch))
        self.weights = rng.dirichlet(np.ones(branch) * 0.5, size=4096)

    def _state(self, a: int, b: int) -> int:
        return (a * 31 + b * 7) % 4096

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length, np.int32)
        a = int(rng.integers(self.vocab))
        b = int(rng.integers(self.vocab))
        for i in range(length):
            s = self._state(a, b)
            t = int(rng.choice(self.table[s], p=self.weights[s]))
            out[i] = t
            a, b = b, t
        return out

    def batches(self, batch: int, seq: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        while True:
            toks = np.stack([self.sample(rng, seq + 1) for _ in range(batch)])
            yield {
                "tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32),
            }


class MemmapDataset:
    """Packed token binary: tokens stored flat; batches are random windows."""

    def __init__(self, path: str, vocab: int, dtype=np.uint16):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.vocab = vocab

    def batches(self, batch: int, seq: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        n = len(self.data) - seq - 1
        while True:
            idx = rng.integers(0, n, size=batch)
            toks = np.stack([self.data[i : i + seq + 1] for i in idx]).astype(np.int32)
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
