"""Hand-written optimizers (no optax in this environment).

AdamW over arbitrary pytrees, with optional cosine learning-rate schedule and
global-norm gradient clipping.  State is a pytree mirroring the params.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamW:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float | None = 1.0
    warmup_steps: int = 0
    total_steps: int | None = None  # enables cosine decay when set

    def init(self, params) -> AdamWState:
        zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))

    def schedule(self, step: jax.Array) -> jax.Array:
        lr = jnp.asarray(self.lr, jnp.float32)
        if self.warmup_steps > 0:
            lr = lr * jnp.minimum(1.0, (step + 1) / self.warmup_steps)
        if self.total_steps is not None:
            frac = jnp.clip(
                (step - self.warmup_steps) / max(self.total_steps - self.warmup_steps, 1), 0.0, 1.0
            )
            lr = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return lr

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = jnp.sqrt(
                sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
            )
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * jnp.square(g), state.nu, grads)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self.schedule(step)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            return (p - lr * (mhat / (jnp.sqrt(vhat) + self.eps) + self.weight_decay * p)).astype(
                p.dtype
            )

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)
