"""Checkpointing: pytree <-> .npz with a flattened key scheme + JSON meta.

No orbax in this environment; .npz keeps the dependency surface at numpy
while preserving dtypes (bf16 stored as uint16 views with a dtype tag).
"""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save_checkpoint(path: str, params, step: int = 0, meta: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(params)
    arrays = {}
    dtypes = {}
    for k, v in flat.items():
        a = np.asarray(v)
        if a.dtype == jnp.bfloat16:
            dtypes[k] = "bfloat16"
            a = a.view(np.uint16)
        arrays[k.replace("/", "__")] = a
    np.savez(path, **arrays)
    with open(path + ".meta.json", "w") as f:
        json.dump({"step": step, "dtypes": dtypes, "meta": meta or {}}, f)


def load_checkpoint(path: str, template=None):
    """Returns (params, step).  With a template pytree the nested structure is
    rebuilt; otherwise a flat {path: array} dict is returned."""
    z = np.load(path, allow_pickle=False)
    with open(path + ".meta.json") as f:
        info = json.load(f)
    flat = {}
    for k in z.files:
        key = k.replace("__", "/")
        a = z[k]
        if info["dtypes"].get(key) == "bfloat16":
            a = a.view(jnp.bfloat16)
        flat[key] = jnp.asarray(a)
    if template is None:
        return flat, info["step"]

    def rebuild(tmpl, prefix=""):
        if isinstance(tmpl, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tmpl.items()}
        if isinstance(tmpl, (list, tuple)):
            return type(tmpl)(rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tmpl))
        return flat[prefix[:-1]]

    return rebuild(template), info["step"]
