"""Speculative-decoding engine: delayed-tree drafting + tree-masked target
pass + lossless verification, with optional NDE action selection.

Two target-pass strategies (DESIGN.md §Arch-applicability):

  * "tree"   — attention-based targets: one batched pass over the speculation
               block with the ancestor mask; accepted KVs are committed
               in-place (slot copy) and stale tree slots invalidated.
  * "replay" — SSM / hybrid targets: a recurrent state has no tree analogue,
               so the trunk is scored in one chunked decode, branches are
               scored by replaying from a state checkpoint (cache fork), and
               commits restore the checkpoint and re-advance along the
               accepted path.  Delayed expansion is a natural fit here: the
               trunk scan is shared and only L2 steps are replayed per branch.

Each request is an independent stream; model calls inside a stream are
batched (branch drafting/replay runs all K branches at once).  The engine is
exact: emitted tokens follow the warped target distribution for every
verifier (property-tested against the core library).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trees import DraftTree, tree_ancestor_mask
from repro.core.verify import VERIFIERS, get_verifier
from repro.models.cache import fork_streams
from repro.models.transformer import forward, init_cache
from repro.sampling import warp_logits
from repro.serving.serve_step import make_pool_commit_step, next_pow2

# top-down OT verifiers with a batched on-device solve (core/otlp_jax.py) —
# derived from registry metadata, not a hand-maintained name list
TOPDOWN = frozenset(n for n, s in VERIFIERS.items() if s.on_device)

VERIFIER_DTYPE = np.float64


def to_verifier_dtype(p: np.ndarray) -> np.ndarray:
    """Cast warped target scores to the dtype the host verifiers consume.

    The ONE verifier-boundary cast shared by both engines and both
    target-pass strategies: verification compares p/q ratios against
    uniform draws in float64, and the cast must live in exactly one place —
    the replay path once hand-rolled its own and drifted (PR-2 notes), which
    a future dtype change would silently repeat."""
    return np.asarray(p, VERIFIER_DTYPE)


def draw_token(rng: np.random.Generator, dist: np.ndarray) -> int:
    """Sample one token from a warped distribution.

    The single draw primitive both engines share: batch-vs-single exactness
    requires identical rng consumption, so neither engine may inline its own
    variant of this."""
    return int(rng.choice(len(dist), p=dist / dist.sum()))


def verify_tree(tree: DraftTree, verifier: str, rng: np.random.Generator):
    """Host-side verifier dispatch — the single mapping both engines share,
    resolved through the core/verify.py registry, so every registered
    verifier works identically under single-stream, batched, sharded and
    pipelined serving.  Returns (accepted_tokens, correction_token)."""
    return get_verifier(verifier).verify(tree, rng)


def _compiled_signatures(fn) -> int:
    """Number of XLA compilations a ``jax.jit`` wrapper holds.  Falls back to
    counting the wrapper itself where jax does not expose the cache size."""
    try:
        return int(fn._cache_size())
    except (AttributeError, TypeError):
        return 1


def fork_cache(cfg, cache: dict, K: int) -> dict:
    """Replicate a single-stream cache K ways along its batch axis.

    Thin wrapper over :func:`repro.models.cache.fork_streams`, which owns the
    per-family batch-axis map (lockstep pos/len stay shared)."""
    return fork_streams(cache, K)


@dataclass
class SamplingParams:
    temperature: float = 1.0
    top_p: float = 1.0


@dataclass
class EngineConfig:
    verifier: str = "specinfer"
    K: int = 2
    L1: int = 2
    L2: int = 2
    max_cache: int = 512
    seed: int = 0
    # run OT verification as a single jitted on-device program
    # (core/otlp_jax.py) instead of host numpy — the TPU deployment path
    verify_on_device: bool = False


class SpeculativeEngine:
    def __init__(self, target_cfg, target_params, draft_cfg, draft_params, ecfg: EngineConfig,
                 sampling: SamplingParams | None = None, selector=None):
        assert target_cfg.vocab == draft_cfg.vocab
        get_verifier(ecfg.verifier)  # fail loudly on unknown names, at build time
        self.tc, self.tp = target_cfg, target_params
        self.dc, self.dp = draft_cfg, draft_params
        self.ecfg = ecfg
        self.sampling = sampling or SamplingParams()
        self.selector = selector  # callable(features) -> (K, L1, L2) or None
        self.rng = np.random.default_rng(ecfg.seed)
        self.strategy = "replay" if target_cfg.arch_type in ("ssm", "hybrid") else "tree"
        self._jit_cache: dict = {}
        # latency accounting (model-call counting for the Eq. 11 throughput model)
        self.counters = {"target_calls": 0, "target_tokens": 0, "draft_calls": 0,
                         "draft_tokens": 0, "accepted": 0, "blocks": 0}

    # ------------------------------------------------------------- helpers ---

    def _jit(self, name, fn, donate_argnums=None):
        """Per-engine jit cache.  ``donate_argnums`` marks pool/cache args
        whose buffers XLA may update in place (the commit path donates the
        cache so committing is a lane-move, not a pool copy)."""
        if name not in self._jit_cache:
            kw = {} if donate_argnums is None else {"donate_argnums": donate_argnums}
            self._jit_cache[name] = jax.jit(fn, **kw)
        return self._jit_cache[name]

    def jit_compile_count(self) -> int:
        """Compiled signatures across this engine's jit cache — the cold-start
        compile budget bench_smoke.sh gates (one cache entry can hold several
        compilations when a name is reused across shapes/dtypes)."""
        return sum(_compiled_signatures(fn) for fn in self._jit_cache.values())

    def _warp(self, logits):
        return warp_logits(logits, self.sampling.temperature, self.sampling.top_p)

    def _draft_decode(self, cache, tokens_np):
        """Run the draft model over T committed/drafted tokens. Returns
        (warped dists (T, V) np, new cache, hidden (T, D))."""
        T = len(tokens_np)
        fn = self._jit(
            f"draft_dec_{T}",
            partial(forward, cfg=self.dc, mode="decode"),
        )
        toks = jnp.asarray(np.asarray(tokens_np, np.int32)[None])
        logits, cache, ex = fn(self.dp, tokens=toks, cache=cache)
        self.counters["draft_calls"] += 1
        self.counters["draft_tokens"] += T
        return np.asarray(self._warp(logits[0])), cache, np.asarray(ex["hidden"][0])

    def _target_pass_tree(self, cache, tree_tokens, anc):
        T = len(tree_tokens)
        fn = self._jit(f"tgt_tree_{T}", partial(forward, cfg=self.tc, mode="tree"))
        logits, cache, ex = fn(
            self.tp,
            tokens=jnp.asarray(np.asarray(tree_tokens, np.int32)[None]),
            cache=cache,
            anc=jnp.asarray(anc[None]),
        )
        self.counters["target_calls"] += 1
        self.counters["target_tokens"] += T
        return np.asarray(self._warp(logits[0])), cache, np.asarray(ex["hidden"][0])

    def _target_decode(self, cache, tokens_np, count=True):
        T = len(tokens_np)
        fn = self._jit(f"tgt_dec_{T}", partial(forward, cfg=self.tc, mode="decode"))
        logits, cache, ex = fn(
            self.tp, tokens=jnp.asarray(np.asarray(tokens_np, np.int32)[None]), cache=cache
        )
        if count:
            self.counters["target_calls"] += 1
            self.counters["target_tokens"] += T
        return np.asarray(self._warp(logits[0])), cache, np.asarray(ex["hidden"][0])

    # -------------------------------------------------------------- stream ---

    def new_stream(self, prompt: list[int], enc_embeds=None, embeds=None) -> dict:
        """Prefill prompt[:-1] into both caches; prompt[-1] is the pending root."""
        assert len(prompt) >= 1
        tcache = init_cache(self.tc, 1, self.ecfg.max_cache)
        dcache = init_cache(self.dc, 1, self.ecfg.max_cache)
        kwargs_t = {}
        if self.tc.arch_type == "encdec":
            kwargs_t["enc_embeds"] = enc_embeds
        if self.tc.arch_type == "vlm" and embeds is not None:
            kwargs_t["embeds"] = embeds
        ctx = prompt[:-1]
        h_p = h_q = None
        if ctx or kwargs_t:
            fn_t = self._jit("tgt_prefill_" + str(len(ctx)), partial(forward, cfg=self.tc, mode="full"))
            _, tcache, ex_t = fn_t(
                self.tp,
                tokens=jnp.asarray(np.asarray(ctx, np.int32)[None]) if ctx else None,
                cache=tcache,
                **{k: v for k, v in kwargs_t.items()},
            )
            h_p = np.asarray(ex_t["hidden"][0, -1])
        if ctx:
            fn_d = self._jit("drf_prefill_" + str(len(ctx)), partial(forward, cfg=self.dc, mode="full"))
            _, dcache, ex_d = fn_d(
                self.dp, tokens=jnp.asarray(np.asarray(ctx, np.int32)[None]), cache=dcache
            )
            h_q = np.asarray(ex_d["hidden"][0, -1])
        d = self.tc.d_model
        dd = self.dc.d_model
        return {
            "tcache": tcache,
            "dcache": dcache,
            "committed": list(prompt),
            "pending": int(prompt[-1]),
            "draft_delta": [int(prompt[-1])],  # tokens the draft hasn't seen
            "h_prev_p": h_p if h_p is not None else np.zeros(d, np.float32),
            "h_prev_q": h_q if h_q is not None else np.zeros(dd, np.float32),
            "p_prev": None,
            "q_prev": None,
            "done": False,
        }

    # ------------------------------------------------------------ drafting ---

    def _draft_tree(self, stream, K, L1, L2):
        """Draft a (K, L1, L2)-delayed tree.  Returns (tree, root_hidden)."""
        rng = self.rng
        dists, dcache, hid = self._draft_decode(stream["dcache"], stream["draft_delta"])
        # dcache is now committed-consistent (delta tokens are committed) —
        # persist it immediately; trunk/branch drafting below works on local
        # functional values that are simply discarded (this also keeps
        # recurrent draft states exact, which a length rollback cannot).
        stream["dcache"] = dcache
        q0 = dists[-1]
        h_cur_q = hid[-1]
        tokens, parent, depth, pid, qs = [-1], [-1], [0], [0], [q0]
        node = 0
        # trunk: sequential single-token drafting
        for _ in range(L1):
            t = draw_token(rng, qs[node])
            d1, dcache, _ = self._draft_decode(dcache, [t])
            tokens.append(t)
            parent.append(node)
            depth.append(depth[node] + 1)
            pid.append(0)
            qs.append(d1[0])
            node = len(tokens) - 1
        branch_node = node
        # branches: fork the draft cache K ways and roll L2 batched steps
        if K > 0 and L2 > 0:
            fork = fork_cache(self.dc, dcache, K)
            # per-branch trackers
            cur_q = np.stack([qs[branch_node]] * K)
            branch_nodes = [branch_node] * K
            for j in range(L2):
                ts = [draw_token(rng, cur_q[k]) for k in range(K)]
                fn = self._jit("draft_branch", partial(forward, cfg=self.dc, mode="decode"))
                logits, fork, _ = fn(
                    self.dp, tokens=jnp.asarray(np.asarray(ts, np.int32)[:, None]), cache=fork
                )
                self.counters["draft_calls"] += 1
                self.counters["draft_tokens"] += K
                dists_b = np.asarray(self._warp(logits[:, 0]))
                for k in range(K):
                    tokens.append(ts[k])
                    parent.append(branch_nodes[k])
                    depth.append(depth[branch_nodes[k]] + 1)
                    pid.append(k)
                    qs.append(dists_b[k])
                    branch_nodes[k] = len(tokens) - 1
        tree = DraftTree(
            tokens=np.asarray(tokens, np.int64),
            parent=np.asarray(parent, np.int64),
            depth=np.asarray(depth, np.int64),
            q=np.stack(qs),
            path_id=np.asarray(pid, np.int64),
        )
        return tree, h_cur_q

    def _rollback_len(self, cache, new_len, cfg):
        cache = dict(cache)
        if "attn" in cache:
            a = dict(cache["attn"])
            a["len"] = jnp.asarray(new_len, jnp.int32)
            cache["attn"] = a
        if "len" in cache:
            cache["len"] = jnp.asarray(new_len, jnp.int32)
        return cache

    # -------------------------------------------------------------- verify ---

    def _verify(self, tree: DraftTree):
        if self.ecfg.verify_on_device and self.ecfg.verifier in TOPDOWN:
            return self._verify_jax(tree, self.ecfg.verifier)
        return verify_tree(tree, self.ecfg.verifier, self.rng)

    def _verify_jax(self, tree: DraftTree, solver: str):
        """On-device whole-tree verification (core/otlp_jax)."""
        from repro.core.otlp_jax import verify_topdown_jax

        N = tree.n_nodes
        max_depth = int(tree.max_depth()) + 1
        max_children = max(self.ecfg.K, 1)
        key = jax.random.PRNGKey(int(self.rng.integers(2**31)))
        out_tok, n_acc, corr = verify_topdown_jax(
            jnp.asarray(tree.tokens.astype(np.int32)),
            jnp.asarray(tree.parent.astype(np.int32)),
            jnp.asarray(tree.p.astype(np.float32)),
            jnp.asarray(tree.q.astype(np.float32)),
            key,
            solver=solver,
            max_depth=max_depth,
            max_children=max_children,
        )
        n = int(n_acc)
        return [int(t) for t in np.asarray(out_tok)[:n]], int(corr)

    @staticmethod
    def _accepted_nodes(tree: DraftTree, accepted: list[int]) -> list[int]:
        """Map the accepted token path -> node indices along the tree.

        Duplicate drafted nodes share a context (and hence KVs/positions), so
        the active *set* is tracked and the first representative is recorded.
        """
        nodes = []
        active = [0]
        for t in accepted:
            kids = [
                i
                for i in range(tree.n_nodes)
                if tree.parent[i] in active and int(tree.tokens[i]) == t
            ]
            nodes.append(kids[0])
            active = kids
        return nodes

    # ------------------------------------------------------------- commits ---

    def _commit_tree_cache(self, cache, C, node_path, T):
        """Copy accepted tree KVs into contiguous committed slots and
        invalidate the remaining tree slots — routed through the same fused
        primitive as the batched engine (serve_step.make_pool_commit_step):
        one jitted, cache-donating call per commit instead of eager
        ``.at[].set`` chains that each copy the whole cache."""
        P = next_pow2(max(1, len(node_path)))
        path = np.zeros((P,), np.int32)
        path[: len(node_path)] = node_path
        fn = self._jit(
            f"commit_T{T}_P{P}", make_pool_commit_step(self.tc, T), donate_argnums=0
        )
        return fn(cache, jnp.asarray(path), np.int32(len(node_path)), np.int32(C))

    # ---------------------------------------------------------------- step ---

    def choose_action(self, stream, q0=None, h_cur_q=None):
        if self.selector is None:
            return self.ecfg.K, self.ecfg.L1, self.ecfg.L2
        return self.selector(stream, self)

    def step(self, stream) -> list[int]:
        """One speculative decoding iteration; returns newly committed tokens."""
        K, L1, L2 = self.choose_action(stream)
        tree, h_cur_q = self._draft_tree(stream, K, L1, L2)
        C = len(stream["committed"]) - 1  # processed target tokens
        T = tree.n_nodes
        tree_tok = tree.tokens.copy()
        tree_tok[0] = stream["pending"]
        anc = tree_ancestor_mask(tree.parent)

        if self.strategy == "tree":
            p_dists, tcache, hid = self._target_pass_tree(stream["tcache"], tree_tok, anc)
            tree.p = to_verifier_dtype(p_dists)
            accepted, corr = self._verify(tree)
            node_path = self._accepted_nodes(tree, accepted)
            stream["tcache"] = self._commit_tree_cache(tcache, C, node_path, T)
            last_node = node_path[-1] if node_path else 0
            stream["h_prev_p"] = hid[last_node]
        else:
            accepted, corr, hid_last = self._verify_replay(stream, tree, tree_tok)
            stream["h_prev_p"] = hid_last

        stream["p_prev"] = tree.p[self._accepted_nodes(tree, accepted)[-1]] if accepted else tree.p[0]
        stream["q_prev"] = tree.q[self._accepted_nodes(tree, accepted)[-1]] if accepted else tree.q[0]
        new_tokens = list(accepted) + [int(corr)]
        stream["committed"].extend(new_tokens)
        stream["pending"] = int(corr)
        stream["draft_delta"] = new_tokens
        stream["h_prev_q"] = h_cur_q
        self.counters["accepted"] += len(accepted)
        self.counters["blocks"] += 1
        return new_tokens

    # -------------------------------------------------- replay (SSM/hybrid) --

    def _verify_replay(self, stream, tree: DraftTree, tree_tok):
        """Target pass for recurrent targets: trunk decode + branch replay."""
        from repro.core.traversal import delayed_structure

        trunk, broot, branches = delayed_structure(tree)
        snapshot = stream["tcache"]  # committed checkpoint (functional arrays)
        trunk_tokens = [int(tree_tok[0])] + [int(tree.tokens[v]) for v in trunk]
        p_seq, cache_after_trunk, hid = self._target_decode(snapshot, trunk_tokens)
        p = np.zeros((tree.n_nodes, tree.vocab), VERIFIER_DTYPE)
        p[0] = p_seq[0]
        for i, v in enumerate(trunk):
            p[v] = p_seq[i + 1]
        if branches:
            K = len(branches)
            L2 = len(branches[0])
            fork = fork_cache(self.tc, cache_after_trunk, K)
            btoks = np.asarray(
                [[int(tree.tokens[v]) for v in path] for path in branches], np.int32
            )
            fn = self._jit(f"tgt_branch_{L2}", partial(forward, cfg=self.tc, mode="decode"))
            logits, _, _ = fn(self.tp, tokens=jnp.asarray(btoks), cache=fork)
            self.counters["target_calls"] += 1
            self.counters["target_tokens"] += K * L2
            pb = np.asarray(self._warp(logits))
            for k, path in enumerate(branches):
                for j, v in enumerate(path):
                    p[v] = pb[k, j]
        tree.p = p
        accepted, corr = self._verify(tree)
        # commit: restore the checkpoint and advance along [root] + accepted
        node_path = self._accepted_nodes(tree, accepted)
        commit_toks = [int(tree_tok[0])] + [int(t) for t in accepted]
        _, new_cache, hid2 = self._target_decode(snapshot, commit_toks, count=False)
        stream["tcache"] = new_cache
        return accepted, int(corr), hid2[-1]

    # ------------------------------------------------------- distribution peeks

    def peek_draft_dist(self, stream, ctx: list[int]) -> np.ndarray:
        """q(. | committed + ctx) without mutating the stream (functional)."""
        toks = list(stream["draft_delta"]) + list(ctx)
        dists, _, _ = self._draft_decode(stream["dcache"], toks)
        return dists[-1]

    def peek_target_dist(self, stream, ctx: list[int]) -> np.ndarray:
        """p(. | committed + ctx) without mutating the stream."""
        toks = [stream["pending"]] + list(ctx)
        dists, _, _ = self._target_decode(stream["tcache"], toks)
        return dists[-1]

    # ------------------------------------------------------------ generate ---

    def generate(self, prompt: list[int], max_new: int = 64, **kw) -> list[int]:
        stream = self.new_stream(prompt, **kw)
        out: list[int] = []
        while len(out) < max_new:
            out.extend(self.step(stream))
        return out[:max_new]
