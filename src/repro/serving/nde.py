"""NDE (neural dynamic expansion) selector wiring for the engine.

Builds App. E features from the stream state, evaluates the selector MLP, and
returns the (K, L1, L2) action.  Also provides the *analytic* selector
(beyond-paper): exhaustive Eq. 9 maximisation using the exact Eq. 3 branching
estimator against the engine's own models.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.delayed import LatencyModel
from repro.core.selector import make_scalar_features, select_action


class NeuralSelector:
    """selector(stream, engine) -> (K, L1, L2) using a trained MLP policy."""

    def __init__(self, params, cfg, latency: LatencyModel, sampling):
        self.params = params
        self.cfg = cfg
        self.latency = latency
        self.sampling = sampling

    def features(self, stream, engine):
        V = engine.tc.vocab
        p_prev = stream.get("p_prev")
        q_prev = stream.get("q_prev")
        if p_prev is None:
            p_prev = np.full(V, 1.0 / V)
        if q_prev is None:
            q_prev = np.full(V, 1.0 / V)
        # q at root: the draft dist produced while ingesting the delta is not
        # yet known at selection time for the *next* root — use q_prev as the
        # freshest proxy (matches "previous token" features of App. E).
        l = len(stream["committed"])
        scal = make_scalar_features(
            p_prev,
            q_prev,
            q_prev,
            l,
            self.sampling.temperature,
            self.sampling.top_p,
            self.latency.t_q(l),
            self.latency.t_p(l),
        )
        return (
            jnp.asarray(stream["h_prev_p"][None]),
            jnp.asarray(stream["h_prev_q"][None]),
            jnp.asarray(stream["h_prev_q"][None]),
            jnp.asarray(scal[None]),
        )

    def __call__(self, stream, engine):
        hp, hq, hc, sc = self.features(stream, engine)
        return select_action(self.params, hp, hq, hc, sc, self.cfg.space)


class StaticSelector:
    def __init__(self, K, L1, L2):
        self.a = (K, L1, L2)

    def __call__(self, stream, engine):
        return self.a


class AnalyticSelector:
    """Beyond-paper oracle: enumerate a small action grid, estimate Eq. 3
    block efficiency with s tree samples against the engine's real draft and
    target, and pick argmax of Ê[tau+1]/T̂ (Eq. 9).  Expensive (extra model
    calls) — used offline to label NDE training data and as an upper bound."""

    def __init__(self, actions, latency: LatencyModel, solver: str, s: int = 1, seed: int = 0):
        self.actions = actions
        self.latency = latency
        self.solver = solver
        self.s = s
        self.rng = np.random.default_rng(seed)

    def __call__(self, stream, engine):
        from repro.core.delayed import estimate_block_efficiency

        # model oracles over *contexts relative to the committed prefix*.
        # Both engines provide them now (the batched engine peeks a gathered
        # pool row); anything else must fail LOUDLY — degrading to a default
        # action here would silently un-do the selector the caller asked for.
        peek_q = getattr(engine, "peek_draft_dist", None)
        peek_p = getattr(engine, "peek_target_dist", None)
        if peek_q is None or peek_p is None:
            raise TypeError(
                f"AnalyticSelector needs peek_draft_dist/peek_target_dist "
                f"oracles, which {type(engine).__name__} does not provide; "
                f"use SpeculativeEngine or BatchedSpeculativeEngine, or switch "
                f"to NeuralSelector/StaticSelector"
            )
        base = list(stream["committed"])

        def q_fn(ctx):
            return peek_q(stream, list(ctx))

        def p_fn(ctx):
            return peek_p(stream, list(ctx))

        best, best_tps = self.actions[0], -1.0
        l = len(base)
        for K, L1, L2 in self.actions:
            eff = estimate_block_efficiency(
                self.rng, q_fn, p_fn, self.solver, K, L1, L2, context=(), s=self.s
            )
            tps = eff / self.latency.action_time(l, K, L1, L2)
            if tps > best_tps:
                best, best_tps = (K, L1, L2), tps
        return best
