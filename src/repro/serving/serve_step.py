"""Jittable batched serving steps — the units the dry-run lowers.

serve_step:      one new token per request against a KV/state cache of
                 ``seq_len`` (the decode_32k / long_500k shapes).
tree_serve_step: one speculation block per request — T tree tokens with a
                 shared topology (the production form of the paper's target
                 pass; used by the benchmarks to price tree passes).
pool steps:      the continuous-batching forms over a per-stream cache pool
                 (models/cache.py): per-row lengths, padded token counts
                 masked by ``lens``, and per-row tree topologies — the units
                 BatchedSpeculativeEngine executes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.cache import merge_streams
from repro.models.transformer import forward


def make_serve_step(cfg):
    """(params, cache, tokens (B, 1)) -> (logits (B, 1, V), new_cache)."""

    def serve_step(params, cache, tokens):
        logits, new_cache, _ = forward(params, cfg, tokens, mode="decode", cache=cache)
        return logits, new_cache

    return serve_step


def make_tree_serve_step(cfg):
    """(params, cache, tokens (B, T), anc (T, T)) -> (logits, new_cache).

    The ancestor mask is shared across the batch (lockstep speculation with a
    common (K, L1, L2) action), matching the engine's batched deployment.
    """

    def tree_step(params, cache, tokens, anc):
        logits, new_cache, _ = forward(params, cfg, tokens, mode="tree", cache=cache, anc=anc)
        return logits, new_cache

    return tree_step


def make_prefill_step(cfg):
    def prefill(params, cache, tokens, enc_embeds=None, embeds=None):
        logits, new_cache, _ = forward(
            params, cfg, tokens, mode="full", cache=cache, enc_embeds=enc_embeds, embeds=embeds
        )
        return logits, new_cache

    return prefill


def make_pool_decode_step(cfg):
    """(params, pool_cache, tokens (B, Tpad), lens (B,)) ->
    (logits, cache, hidden).

    Padded decode over a per-stream pool: row b's tokens beyond lens[b] are
    written but invalidated (pos = -1), so heterogeneous per-stream deltas
    advance in one call.  Attention-family archs only (recurrent state
    cannot be length-masked — use make_pool_locked_step)."""

    def step(params, cache, tokens, lens):
        logits, new_cache, ex = forward(params, cfg, tokens, mode="decode", cache=cache, lens=lens)
        return logits, new_cache, ex["hidden"]

    return step


def make_pool_locked_step(cfg):
    """(params, pool_cache, tokens (B, 1), keep (B,)) -> (logits, cache).

    One lockstep token per stream; rows with keep=False are frozen at their
    exact prior state (merge_streams), which is the recurrent-safe padding
    primitive."""

    def step(params, cache, tokens, keep):
        logits, new_cache, _ = forward(params, cfg, tokens, mode="decode", cache=cache)
        return logits, merge_streams(new_cache, cache, keep)

    return step


def make_pool_tree_step(cfg):
    """(params, pool_cache, tokens (B, Tpad), anc (B, Tpad, Tpad)) ->
    (logits, cache, hidden).

    The continuous-batching target pass: per-row tree topologies over a
    per-stream cache pool.  Padding nodes are isolated roots (anc = self
    only) — never attended by real nodes and invalidated at commit."""

    def tree_step(params, cache, tokens, anc):
        logits, new_cache, ex = forward(params, cfg, tokens, mode="tree", cache=cache, anc=anc)
        return logits, new_cache, ex["hidden"]

    return tree_step
