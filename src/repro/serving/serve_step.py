"""Jittable batched serving steps — the units the dry-run lowers.

serve_step:      one new token per request against a KV/state cache of
                 ``seq_len`` (the decode_32k / long_500k shapes).
tree_serve_step: one speculation block per request — T tree tokens with a
                 shared topology (the production form of the paper's target
                 pass; used by the benchmarks to price tree passes).
pool steps:      the continuous-batching forms over a per-stream cache pool
                 (models/cache.py): per-row lengths, padded token counts
                 masked by ``lens``, per-row tree topologies, and the fused
                 post-verification commit — the units
                 BatchedSpeculativeEngine executes.  Per-step host->device
                 traffic for these is index arrays only: ancestor masks are
                 composed on device from parent pointers and the commit is
                 driven by (node_path, path_len, C) tables.

Every step here is verifier-agnostic by design: verification is host-side
per stream, resolved through the core/verify.py registry (engine.verify_tree),
and the device steps only ever see its *outcome* as (node_path, path_len)
commit tables.  That contract is what lets any registered verifier run under
batched, sharded and pipelined serving token-identically with zero changes
to the compiled step set.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import pool_commit_kv
from repro.models.cache import merge_streams, paged_phys_slots
from repro.models.transformer import forward


def next_pow2(n: int) -> int:
    """Smallest power of two >= n — the shape-bucketing rule shared by both
    engines (bounds the jit cache under heterogeneous per-stream shapes)."""
    p = 1
    while p < n:
        p *= 2
    return p


class StagingBuffers:
    """Reusable host staging buffers for the per-step index arrays.

    Every pool step ships a handful of small int/bool arrays (tokens, parent
    pointers, commit tables); staging them in preallocated numpy buffers
    keeps the steady-state serving loop allocation-free on the host side.

    ``banks`` > 1 double-buffers the staging itself: ``flip()`` rotates to
    the next bank, so a pipelined engine refilling buffers for step i+1
    never touches the bank step i's arrays were built from.  ``jnp.asarray``
    copies host memory eagerly at dispatch today, so a single bank is safe
    for the synchronous engine — the bank flip makes the pipelined engine's
    no-overwrite contract explicit instead of resting on that copy timing.

    Staging is strictly per-engine: every shard of a sharded engine
    (ShardedBatchedSpeculativeEngine) owns its own instance, so its
    (per-shard-sized) tree/commit index arrays and bank rotation can never
    alias another shard's — shard isolation by construction, not by key.
    """

    def __init__(self, banks: int = 1):
        assert banks >= 1
        self._banks = banks
        self._bank = 0
        self._bufs: dict = {}

    def flip(self) -> None:
        """Rotate to the next bank (a pipelined ``begin_step`` boundary)."""
        self._bank = (self._bank + 1) % self._banks

    def get(self, name: str, shape: tuple, dtype, fill=0) -> np.ndarray:
        """A zeroed (or ``fill``-initialised) buffer of the given shape from
        the current bank, reused across steps with the same shape bucket."""
        key = (self._bank, name, shape)
        buf = self._bufs.get(key)
        if buf is None:
            buf = self._bufs[key] = np.empty(shape, dtype)
        buf.fill(fill)
        return buf


def make_serve_step(cfg):
    """(params, cache, tokens (B, 1)) -> (logits (B, 1, V), new_cache)."""

    def serve_step(params, cache, tokens):
        logits, new_cache, _ = forward(params, cfg, tokens, mode="decode", cache=cache)
        return logits, new_cache

    return serve_step


def make_tree_serve_step(cfg):
    """(params, cache, tokens (B, T), anc (T, T)) -> (logits, new_cache).

    The ancestor mask is shared across the batch (lockstep speculation with a
    common (K, L1, L2) action), matching the engine's batched deployment.
    """

    def tree_step(params, cache, tokens, anc):
        logits, new_cache, _ = forward(params, cfg, tokens, mode="tree", cache=cache, anc=anc)
        return logits, new_cache

    return tree_step


def make_prefill_step(cfg):
    def prefill(params, cache, tokens, enc_embeds=None, embeds=None):
        logits, new_cache, _ = forward(
            params, cfg, tokens, mode="full", cache=cache, enc_embeds=enc_embeds, embeds=embeds
        )
        return logits, new_cache

    return prefill


def make_pool_decode_step(cfg):
    """(params, pool_cache, tokens (B, Tpad), lens (B,)) ->
    (logits, cache, hidden).

    Padded decode over a per-stream pool: row b's tokens beyond lens[b] are
    written but invalidated (pos = -1), so heterogeneous per-stream deltas
    advance in one call.  Attention-family archs only (recurrent state
    cannot be length-masked — use make_pool_locked_step)."""

    def step(params, cache, tokens, lens):
        logits, new_cache, ex = forward(params, cfg, tokens, mode="decode", cache=cache, lens=lens)
        return logits, new_cache, ex["hidden"]

    return step


def make_pool_locked_step(cfg):
    """(params, pool_cache, tokens (B, 1), keep (B,)) -> (logits, cache).

    One lockstep token per stream; rows with keep=False are frozen at their
    exact prior state (merge_streams), which is the recurrent-safe padding
    primitive."""

    def step(params, cache, tokens, keep):
        logits, new_cache, _ = forward(params, cfg, tokens, mode="decode", cache=cache)
        return logits, merge_streams(new_cache, cache, keep)

    return step


def device_ancestor_mask(parents: jax.Array) -> jax.Array:
    """Compose per-row ancestor-or-self masks on device from parent pointers.

    parents: (B, T) int32, parent[b, i] = parent node of i, -1 for the root
    and for padding nodes (which become isolated roots, exactly the padding
    convention of the tree pass).  Returns (B, T, T) bool with
    mask[b, i, j] == True iff j is an ancestor of i or i == j — bit-identical
    to host-side ``core.trees.tree_ancestor_mask`` per row.

    This keeps the per-step H2D transfer at (B, T) index arrays instead of
    the dense (B, T, T) mask tensor the host used to rebuild every iteration.
    T chain-follow iterations bound any tree depth; each is a (B, T, T) OR.
    """
    B, T = parents.shape
    anc0 = jnp.broadcast_to(jnp.eye(T, dtype=bool)[None], (B, T, T))
    cur0 = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(_, carry):
        anc, cur = carry
        nxt = jnp.where(
            cur >= 0, jnp.take_along_axis(parents, jnp.maximum(cur, 0), axis=1), -1
        )
        anc = anc | (jnp.arange(T, dtype=jnp.int32)[None, None, :] == nxt[:, :, None])
        return anc, nxt

    anc, _ = jax.lax.fori_loop(0, T, body, (anc0, cur0))
    return anc


def make_pool_tree_step(cfg):
    """(params, pool_cache, tokens (B, Tpad), parents (B, Tpad), keep (B,))
    -> (logits, cache, hidden).

    The continuous-batching target pass: per-row tree topologies over a
    per-stream cache pool.  The ancestor masks are composed on device from
    parent pointers (device_ancestor_mask) and rows with keep=False are
    frozen at their exact prior state inside the same jit call, so the host
    ships only (B, Tpad) index arrays per step.  Padding nodes carry
    parent = -1 (isolated roots) — never attended by real nodes and
    invalidated at commit."""

    def tree_step(params, cache, tokens, parents, keep):
        anc = device_ancestor_mask(parents)
        logits, new_cache, ex = forward(params, cfg, tokens, mode="tree", cache=cache, anc=anc)
        # idle slots must not advance; active rows keep the tree writes the
        # fused commit relies on
        return logits, merge_streams(new_cache, cache, keep), ex["hidden"]

    return tree_step


def make_pool_ragged_tree_step(cfg):
    """(params, pool_cache, toks (Npad,), owner, parent, depth, local,
    counts) -> (logits (Npad, V), cache, hidden (Npad, d)).

    The RAGGED continuous-batching target pass: every active stream's tree
    flattened into ONE node-major buffer instead of padding each row to the
    pool-wide Tpad (docs/serving.md "Ragged node-major tree batching").
    ``owner``/``parent``/``depth``/``local`` are per-node (Npad,) index
    arrays, ``counts`` the per-row (B,) appended-node counts; padding lanes
    carry local = -1/parent = -1 and write NOTHING (their ring slot is the
    out-of-range sentinel, so every drop-mode scatter vanishes) — which is
    also why no merge_streams is needed: idle rows advance by counts = 0
    and never see a stale write to undo.  Node j of stream s lands in the
    exact ring slot padded column j would, so the fused commit
    (make_pool_commit_step) is shared verbatim between both layouts."""

    def ragged_tree_step(params, cache, toks, owner, parent, depth, local, counts):
        logits, new_cache, ex = forward(
            params, cfg, toks[None], mode="tree", cache=cache,
            ragged={"owner": owner, "parent": parent, "depth": depth,
                    "local": local, "counts": counts},
        )
        return logits[0], new_cache, ex["hidden"][0]

    return ragged_tree_step


def make_pool_commit_step(cfg, Tpad: int):
    """Fused post-verification commit: ONE jitted call re-compacts every
    stream's accepted path in the KV ring, invalidates its speculative
    slots and advances its length — O(touched lanes) data movement instead
    of O(active_streams) full-pool copies.  Jit with ``donate_argnums=0``
    (both engines do) and XLA updates the pool buffers in place.

    Returned fn: (cache, node_path, path_len, C, active) -> cache
      node_path (B, P) int32 : accepted tree-node indices per row, padded
      path_len  (B,)   int32 : number of real entries per row (0 for rows
                               that accepted nothing, and for idle rows)
      C         (B,)   int32 : committed target length before the block
                               (the pending root sits at ring slot C % smax)
      active    (B,)   bool  : rows that ran a tree pass this iteration;
                               inactive rows are bit-identical no-ops

    The single-stream lockstep layout is also accepted (node_path (P,),
    scalar path_len/C, active ignored): the slot math is then shared across
    the batch axis, mirroring SpeculativeEngine's cache.

    Index contract (models/cache.py "Ring-compaction commit contract",
    documented in full in docs/kernels.md):
    padded/idle entries are identity copies of the root slot
    (src == dst == C % smax), which no real entry writes; accepted node
    indices are strictly increasing with n_j >= j + 1, so a src slot is
    never an EARLIER entry's dst slot and dst slots are pairwise distinct —
    the hazard-free property that lets the Pallas kernel's sequential
    in-place grid read every lane's pre-commit value.

    Paged pools (models/cache.py paged layout) run the same logical-slot
    arithmetic, then translate src/dst through the per-row block table
    into flat arena lanes and issue ONE pool_commit_kv over the arena
    viewed as a single-row pool: rows own disjoint physical blocks, so
    concatenating every row's entries row-major preserves the hazard-free
    property (idle/unmapped entries translate into the trash block with
    src == dst).  pos/len/block_tbl stay logical and untouched by the move.
    """
    use_pallas = cfg.attention_impl == "pallas"
    interpret = cfg.kernel_interpret

    def commit(cache, node_path, path_len, C, active=None):
        a = cache["attn"]
        k, v, pos = a["k"], a["v"], a["pos"]
        paged = "block_tbl" in a
        smax = pos.shape[-1] if pos.ndim == 2 else pos.shape[0]
        P = node_path.shape[-1]
        j = jnp.arange(P, dtype=jnp.int32)
        t = jnp.arange(Tpad, dtype=jnp.int32)
        jj = jnp.arange(P + 1, dtype=jnp.int32)
        if pos.ndim == 2:  # per-stream pool (ring or paged)
            B = pos.shape[0]
            bidx = jnp.arange(B)[:, None]
            valid = j[None, :] < path_len[:, None]
            root = (C % smax)[:, None]
            src = jnp.where(valid, (C[:, None] + node_path) % smax, root)
            dst = jnp.where(valid, (C[:, None] + 1 + j[None, :]) % smax, root)
            if paged:
                tbl = a["block_tbl"]
                block = k.shape[2]
                nl = k.shape[0]
                srcf = paged_phys_slots(tbl, src, block).reshape(1, -1)
                dstf = paged_phys_slots(tbl, dst, block).reshape(1, -1)
                kf = k.reshape((nl, 1, k.shape[1] * block) + k.shape[3:])
                vf = v.reshape((nl, 1, v.shape[1] * block) + v.shape[3:])
                kf, vf = pool_commit_kv(
                    kf, vf, srcf.astype(jnp.int32), dstf.astype(jnp.int32),
                    use_pallas=use_pallas, interpret=interpret,
                )
                k, v = kf.reshape(k.shape), vf.reshape(v.shape)
            else:
                k, v = pool_commit_kv(
                    k, v, src.astype(jnp.int32), dst.astype(jnp.int32),
                    use_pallas=use_pallas, interpret=interpret,
                )
            new_pos = pos.at[bidx, (C[:, None] + t[None, :]) % smax].set(-1)
            keep_valid = jj[None, :] <= path_len[:, None]
            keep_slots = jnp.where(keep_valid, (C[:, None] + jj[None, :]) % smax, root)
            keep_vals = jnp.where(keep_valid, C[:, None] + jj[None, :], C[:, None])
            new_pos = new_pos.at[bidx, keep_slots].set(keep_vals)
            new_pos = jnp.where(active[:, None], new_pos, pos)
            new_len = jnp.where(active, C + 1 + path_len, a["len"])
        else:  # lockstep single-stream cache (shared pos/len tables)
            valid = j < path_len
            root = C % smax
            src = jnp.where(valid, (C + node_path) % smax, root)
            dst = jnp.where(valid, (C + 1 + j) % smax, root)
            k = k.at[:, :, dst].set(k[:, :, src])
            v = v.at[:, :, dst].set(v[:, :, src])
            new_pos = pos.at[(C + t) % smax].set(-1)
            keep_valid = jj <= path_len
            keep_slots = jnp.where(keep_valid, (C + jj) % smax, root)
            keep_vals = jnp.where(keep_valid, C + jj, C)
            new_pos = new_pos.at[keep_slots].set(keep_vals)
            new_len = (C + 1 + path_len).astype(jnp.int32)
        cache = dict(cache)
        new_attn = {"k": k, "v": v, "pos": new_pos, "len": new_len}
        if paged:
            new_attn["block_tbl"] = a["block_tbl"]
        cache["attn"] = new_attn
        return cache

    return commit


def make_group_commit_step(cfg, tpads: list[int]):
    """Grouped cross-shard commit: fuse N shard pools' post-verification
    commits into ONE jitted dispatch.

    The sharded engine's shards each own a private pool, so stepping them
    as a host loop pays one commit dispatch (and, with ``profile_commits``,
    one blocking sync) per shard per iteration — the 9 -> 17 ``commit_calls``
    regression the baselines recorded.  Shard pools are disjoint arrays, so
    their commits compose into a single program with no interference: this
    builds one ``make_pool_commit_step`` per shard (each with its own
    ``Tpad`` — shards bucket their speculation shapes independently) and
    applies them elementwise over tuples.

    Returned fn: (caches, node_paths, path_lens, Cs, actives) -> caches,
    every argument a length-N tuple in shard order, with per-shard index
    contracts exactly as in ``make_pool_commit_step``.  Jit with
    ``donate_argnums=0`` (the engine does) and XLA updates every shard's
    pool buffers in place in the one fused program.  Only valid when the
    shard pools are device-colocated (the engine checks); on multi-host
    topologies shards keep their per-shard commit calls."""
    fns = [make_pool_commit_step(cfg, T) for T in tpads]

    def group_commit(caches, node_paths, path_lens, Cs, actives):
        assert len(caches) == len(fns), (len(caches), len(fns))
        return tuple(
            fn(cache, npath, plen, C, act)
            for fn, cache, npath, plen, C, act
            in zip(fns, caches, node_paths, path_lens, Cs, actives)
        )

    return group_commit


def commit_row_reference(cache, slot: int, C: int, node_path, T: int):
    """PR-1 per-row sequential commit (eager ``.at[].set`` chains): the
    bit-exactness oracle the fused commit is property-tested and benchmarked
    against (tests/test_commit_fused.py, benchmarks/commit_bench.py).  Each
    call materializes a fresh copy of the whole pool — the O(active_streams)
    cost make_pool_commit_step removes."""
    a = cache["attn"]
    smax = a["k"].shape[2]
    tree_slots = (C + np.arange(T)) % smax
    src = [(C + n) % smax for n in node_path]
    dst = [(C + 1 + i) % smax for i in range(len(node_path))]
    k, v, pos = a["k"], a["v"], a["pos"]
    if src:
        src_i = jnp.asarray(src)
        dst_i = jnp.asarray(dst)
        k = k.at[:, slot, dst_i].set(k[:, slot, src_i])
        v = v.at[:, slot, dst_i].set(v[:, slot, src_i])
    pos = pos.at[slot, jnp.asarray(tree_slots)].set(-1)
    keep = np.asarray([(C + i) % smax for i in range(1 + len(node_path))])
    pos = pos.at[slot, jnp.asarray(keep)].set(
        jnp.asarray(C + np.arange(1 + len(node_path)), jnp.int32)
    )
    new_len = a["len"].at[slot].set(C + 1 + len(node_path))
    cache = dict(cache)
    cache["attn"] = {"k": k, "v": v, "pos": pos, "len": new_len}
    return cache
