"""Jittable batched serving steps — the units the dry-run lowers.

serve_step:      one new token per request against a KV/state cache of
                 ``seq_len`` (the decode_32k / long_500k shapes).
tree_serve_step: one speculation block per request — T tree tokens with a
                 shared topology (the production form of the paper's target
                 pass; used by the benchmarks to price tree passes).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.transformer import forward


def make_serve_step(cfg):
    """(params, cache, tokens (B, 1)) -> (logits (B, 1, V), new_cache)."""

    def serve_step(params, cache, tokens):
        logits, new_cache, _ = forward(params, cfg, tokens, mode="decode", cache=cache)
        return logits, new_cache

    return serve_step


def make_tree_serve_step(cfg):
    """(params, cache, tokens (B, T), anc (T, T)) -> (logits, new_cache).

    The ancestor mask is shared across the batch (lockstep speculation with a
    common (K, L1, L2) action), matching the engine's batched deployment.
    """

    def tree_step(params, cache, tokens, anc):
        logits, new_cache, _ = forward(params, cfg, tokens, mode="tree", cache=cache, anc=anc)
        return logits, new_cache

    return tree_step


def make_prefill_step(cfg):
    def prefill(params, cache, tokens, enc_embeds=None, embeds=None):
        logits, new_cache, _ = forward(
            params, cfg, tokens, mode="full", cache=cache, enc_embeds=enc_embeds, embeds=embeds
        )
        return logits, new_cache

    return prefill
