"""Continuous-batching speculative engine: N concurrent streams per model call.

``SpeculativeEngine`` (serving/engine.py) advances one stream per target /
draft call, so multi-user throughput is bounded by single-stream latency.
This module packs every active stream into lockstep batched calls — per
iteration one padded draft-ingest pass, one padded draft step per tree level,
ONE padded tree-masked target pass, and ONE jitted pool-donating commit —
with per-stream host verification, so aggregate tokens/sec scales with the
number of streams while each stream's output remains exactly the warped
target process.  The commit path is device-resident: host->device traffic
per step is small index arrays (tokens, parent pointers, accepted-path
tables) staged in reusable buffers; ancestor masks are composed on device
and the ring compaction moves only touched (row, slot) KV lanes
(serve_step.make_pool_commit_step / kernels/commit_kv.py) instead of
copying the pool once per stream.

Substrate (models/cache.py): a slot-based per-stream KV pool.  Every model
call sees the same (n_slots, ...) shapes, so streams join (prefill a 1-row
cache, scatter it into a free slot) and leave (release the slot) without
recompiles.  Speculation shapes are BUCKETED: per-iteration (K, L1, L2) are
padded to the next power of two, so the jit cache stays bounded even under
heterogeneous per-stream NDE selector decisions.

By default the attention KV is PAGED (``paged=True``): instead of reserving
a full ``max_cache`` ring per slot, KV lives in a shared arena of
``block_size``-slot blocks indexed through per-stream block tables
(models/cache.py paged layout), so HBM holds only the blocks streams have
actually written — one long stream and many short ones co-reside in a pool
a ring design could not share.  Block pressure is handled in three stages
before any stream dies: admission is gated on the free list, dead tail
blocks past each stream's live frontier are recycled
(``counters["blocks_reclaimed"]``), and only then is the most recently
admitted stream evicted (LIFO — the oldest streams keep their residency).
With ``pool_blocks`` left at its default (n_slots * max_cache / block_size,
i.e. ring-equivalent capacity) scheduling decisions are identical to the
ring pool and the output is token-identical to it (property-tested in
tests/test_paged_pool.py).  See docs/serving.md for the full lifecycle.

Exactness contract (property-tested in tests/test_batch_engine.py): with the
same per-stream seed, the batched engine emits token-identical output to an
independent ``SpeculativeEngine`` run per stream.  This leans on three facts:

  * attention/MoE/MLP compute is per-row and per-query: padding extra rows
    (idle slots) or extra query tokens (masked via ``lens`` / the ancestor
    mask) contributes exact zeros to softmax sums, so logits are bit-equal
    to the unpadded single-stream call (verified: dense/ssm/hybrid logits
    are invariant to batch size on the XLA CPU/TPU paths);
  * MoE routing is dropless (models/moe.py), so expert outputs do not
    depend on batch co-tokens;
  * recurrent (ssm/rglru) state integrates *every* processed token and the
    chunked SSD scan is not bitwise-stable under length padding, so
    recurrent-arch multi-token calls are grouped by exact length (same T as
    the single engine) instead of padded, and T=1 lockstep steps are frozen
    per-row with ``merge_streams``.

Scheduling: admission is FIFO (``submit`` queues, free slots admit); a stream
is evicted (finished early) when its context can no longer fit a speculation
block in its cache ring.  ``launch/serve.py --streams N`` drives this engine.

Sharded streams (``ShardedBatchedSpeculativeEngine``, docs/serving.md
"Sharded streams"): the pool's stream axis is embarrassingly parallel, so
it shards across a mesh "data" axis — contiguous slot shards, each a full
engine over its own rows/arena/free-lists/admission-queue with its pool
arrays NamedSharding-committed to its mesh slice, under a shared
least-loaded scheduler.  No cross-shard state exists beyond the routing
decision, which is the property that scales the pool past one chip's HBM.

Pipelined stepping (``pipeline=True``, docs/serving.md "Pipelined stepping"):
``step()`` is built from phases — ``begin_step()`` runs the scheduling
boundary (admission, capacity eviction, paged block mapping) and dispatches
the draft + tree-pass device work, returning a ``PendingStep`` whose tree
outputs are still device futures; ``verify_step()`` blocks on those futures
and verifies per stream on host, ``commit_step()`` issues the fused commit,
and ``retire_step()`` advances token bookkeeping and dispatches the NEXT
step's draft/tree work before the host tail (the hidden-state readback and
stream retirement), so step i's tail overlaps step i+1's device work.
``finish_step()`` is the composition of the last three.  Scheduling — and
therefore tokens — stays identical to the synchronous engine because every
retiring stream's slot/block release lands BEFORE the begun-ahead boundary
(the boundary sees exactly the post-release pool a synchronous
``begin_step`` would), and a begun step can be drained (``drain_pipeline``)
or rewound (``abort_step``, ``abort_pipeline``) when out-of-band events —
a mid-run ``submit`` against a free row — would have changed it.  The rewind is LOGICAL for attention-family draft pools —
ingest writes are append-only and deterministic, so ``invalidate_from``
erases them and the re-begun step re-ingests identical lanes — and only
recurrent draft pools hold the double-buffered back frame (models/cache.py
``begin_frame``): keeping the pre-step arena alive was the pipelined mode's
single biggest overhead.
"""
from __future__ import annotations

import time
from collections import defaultdict
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.traversal import delayed_structure
from repro.core.trees import DraftTree
from repro.core.verify import get_verifier
from repro.launch.mesh import shard_meshes
from repro.launch.sharding import pad_slots, pool_shardings
from repro.models.cache import (
    PagedCachePool,
    concat_streams,
    fork_streams,
    gather_streams,
    make_cache_pool,
    scatter_streams,
)
from repro.models.transformer import forward, init_cache
from repro.sampling import warp_logits
from repro.serving.engine import (
    EngineConfig,
    SamplingParams,
    SpeculativeEngine,
    _compiled_signatures,
    draw_token,
    to_verifier_dtype,
    verify_tree,
)
from repro.serving.serve_step import (
    StagingBuffers,
    make_group_commit_step,
    make_pool_commit_step,
    make_pool_decode_step,
    make_pool_locked_step,
    make_pool_ragged_tree_step,
    make_pool_tree_step,
    next_pow2 as _next_pow2,
)

RECURRENT = ("ssm", "hybrid")


@dataclass
class BatchRequest:
    rid: int
    prompt: list
    max_new: int
    seed: int


@dataclass
class PendingStep:
    """A dispatched-but-unverified iteration: everything ``finish_step``
    needs to verify, commit and retire it.

    For the tree strategy ``p_dev``/``hid_dev`` are *device* arrays (the
    warped tree-pass logits and hidden states, with async host copies
    already kicked off) — the futures the pipeline overlaps host work
    against.  The replay strategy's target pass is host-interleaved, so it
    arrives already materialised as ``snapshot``/``p_host``.

    ``C0`` (committed length minus the pending root, per slot), ``D0``
    (the draft pool's pre-ingest length, per slot — attention-family draft
    pools rewind logically instead of holding a back frame) and
    ``rng_state`` (per-stream generator snapshots, pipelined mode only)
    are the rewind coordinates ``abort_step`` uses."""

    active: list[int]
    acts: dict[int, tuple]
    pads: tuple[int, int, int, int]
    trees: dict
    hq: dict
    C0: dict[int, int]
    p_dev: object = None
    hid_dev: object = None
    snapshot: dict | None = None
    p_host: dict | None = None
    rng_state: dict | None = None
    D0: dict[int, int] | None = None
    # tree strategy, ragged layout only: ({slot: (offset, n_nodes)}, Npad)
    # — how the flat node-major logits/hidden buffers slice back into
    # per-stream trees (None = padded (B, Tpad) layout)
    roffs: object = None
    # True when this step's scheduling boundary evicted a stream: its slot
    # and block releases stand, so replaying admission against the
    # post-eviction pool would not reproduce the synchronous
    # admit-before-evict order (submit()'s drain-vs-abort rule)
    boundary_evicted: bool = False


@dataclass
class VerifiedStep:
    """A verified-but-unretired iteration: ``verify_step``'s per-stream
    accept/correction decisions, ready for ``commit_step`` (which fills
    ``hid_last`` on the replay strategy) and ``retire_step``.

    The split exists so a driver holding several engines — the sharded
    engine's concurrent ``step()`` — can verify every shard against the
    others' in-flight device work, then batch the commits into one
    dispatch before any shard retires."""

    pending: PendingStep
    accepted: dict[int, list]
    corr: dict[int, int]
    node_paths: dict | None = None   # tree strategy: accepted node index paths
    hid_last: dict | None = None     # replay strategy: filled by commit_step


class BatchedSpeculativeEngine:
    """Multi-stream speculative decoding over a slot-based cache pool.

    API:  ``submit(prompt, max_new, seed) -> rid``; ``step()`` advances every
    active stream one speculative block (admitting queued requests first) and
    returns per-request progress; ``run()`` drains the queue and returns
    ``{rid: tokens}``.
    """

    def __init__(self, target_cfg, target_params, draft_cfg, draft_params,
                 ecfg: EngineConfig, sampling: SamplingParams | None = None,
                 selector=None, n_slots: int = 4, paged: bool = True,
                 block_size: int = 64, pool_blocks: int | None = None,
                 pipeline: bool = False, mesh=None, shard_id: int = 0,
                 ragged=True):
        assert target_cfg.vocab == draft_cfg.vocab
        assert n_slots >= 1, f"need at least one pool slot, got {n_slots}"
        assert target_cfg.arch_type not in ("encdec", "vlm"), \
            "batched serving covers decoder-only archs (encdec/vlm prefill kwargs are single-stream)"
        assert not ecfg.verify_on_device, \
            "batched serving verifies per-stream on host (verify_on_device consumes " \
            "randomness differently and would break batch-vs-single exactness)"
        get_verifier(ecfg.verifier)  # fail loudly on unknown names, at build time
        self.tc, self.tp = target_cfg, target_params
        self.dc, self.dp = draft_cfg, draft_params
        self.ecfg = ecfg
        self.sampling = sampling or SamplingParams()
        self.selector = selector
        self.n_slots = n_slots
        # mesh: a jax mesh whose "data" axis carries this engine's pool
        # stream axis (launch/sharding.pool_shardings commits the pool
        # arrays to it; n_slots must divide the axis — pad_slots).  The
        # sharded engine hands every shard its own single-device mesh slice
        # (launch/mesh.shard_meshes); a multi-device data mesh on one
        # engine shards the one pool SPMD-style instead.
        self.mesh = mesh
        self.shard_id = shard_id
        self.strategy = "replay" if target_cfg.arch_type in RECURRENT else "tree"
        smax = ecfg.max_cache
        page = None
        if paged:
            bs = self.normalize_block_size(smax, block_size)
            self.block_size = bs
            self.max_blocks = smax // bs
            if pool_blocks is None:
                # ring-equivalent capacity: scheduling (admission/eviction)
                # is then identical to the ring pool, and so is the output
                pool_blocks = n_slots * self.max_blocks
            # an arena smaller than one logical ring is legal: streams that
            # outgrow it are pressure-evicted (submit() rejects prompts that
            # could never fit at all)
            assert pool_blocks >= 1, "the arena needs at least one usable block"
            self.pool_blocks = pool_blocks
            page = (pool_blocks, bs)
        tcache = init_cache(target_cfg, n_slots, smax, per_stream=True, page=page)
        dcache = init_cache(draft_cfg, n_slots, smax, per_stream=True, page=page)
        self.tpool = make_cache_pool(
            tcache, n_slots,
            sharding=pool_shardings(mesh, tcache) if mesh is not None else None)
        self.dpool = make_cache_pool(
            dcache, n_slots,
            sharding=pool_shardings(mesh, dcache) if mesh is not None else None)
        # pure-recurrent caches have no attn component to page
        self.paged = isinstance(self.tpool, PagedCachePool) or isinstance(self.dpool, PagedCachePool)
        # ragged node-major tree pass (docs/serving.md): False = always the
        # padded (B, Tpad) layout; True = auto (ragged whenever the flat
        # buffer is strictly smaller than the padded lane count — drain
        # tails, heterogeneous selector actions); "always" = every tree
        # step, regardless (the exactness tests force both layouts onto
        # identical workloads).  The pallas impl needs the block-table
        # kernel's Q-steering, so pallas + a non-paged (ring) target pool
        # keeps the padded path.
        self.ragged = ragged
        self._ragged_ok = (
            bool(ragged)
            and self.strategy == "tree"
            and target_cfg.arch_type in ("dense", "moe")
            and not (target_cfg.attention_impl == "pallas"
                     and not isinstance(self.tpool, PagedCachePool))
        )
        # pallas Q tiles are 8 rows of uniform owner, so segment offsets
        # 8-align there; the XLA gather path packs nodes back-to-back
        self._ragged_align = 8 if target_cfg.attention_impl == "pallas" else 1
        self.streams: dict[int, dict] = {}  # slot -> stream state
        self.queue: list[BatchRequest] = []
        self.finished: dict[int, dict] = {}
        self._next_rid = 0
        self._admit_seq = 0
        self._jit_cache: dict = {}
        # pipelined mode double-banks the staging so refilling step i+1's
        # index arrays never touches the bank step i was built from
        self.pipeline = pipeline
        self._staging = StagingBuffers(banks=2 if pipeline else 1)
        self._pending_next: PendingStep | None = None
        self._drained_events: list[dict] = []  # retired by submit(), not yet returned
        # commit_ms times the dispatch only unless profile_commits is set
        # (benchmarks set it): blocking on the commit every step would
        # serialize host bookkeeping against the device op it just saved.
        self.profile_commits = False
        # pipeline_iterations counts every pipeline-ahead decision point, and
        # each decision either runs ahead or stalls — so
        # pipeline_ahead + pipeline_stalls == pipeline_iterations holds by
        # construction (the race-harness invariant, tests/test_race.py)
        # pad_nodes_total / tree_lanes_total: padding-waste accounting for
        # the tree pass — lanes the dispatch shipped vs real tree nodes
        # (pad_fraction = pad_nodes_total / tree_lanes_total); the ragged
        # layout exists to shrink it (benchmarks/batch_throughput.py gates
        # it under the heterogeneous scenario)
        self.counters = {"target_calls": 0, "target_tokens": 0, "draft_calls": 0,
                         "draft_tokens": 0, "accepted": 0, "blocks": 0, "evicted": 0,
                         "commit_calls": 0, "commit_ms": 0.0,
                         "blocks_reclaimed": 0, "admit_blocked": 0, "blocks_peak": 0,
                         "pad_nodes_total": 0, "tree_lanes_total": 0,
                         "pipeline_ahead": 0, "pipeline_stalls": 0,
                         "pipeline_iterations": 0}

    def reset_counters(self, keys) -> None:
        """Zero the named counters (shared surface with the sharded engine —
        benchmarks reset per-pass counters through one call either way)."""
        for key in keys:
            self.counters[key] = type(self.counters[key])()

    # ------------------------------------------------------------- helpers ---

    @staticmethod
    def normalize_block_size(smax: int, block_size: int) -> int:
        """The block size must tile the logical ring exactly: round the
        request down to a power of two first (48 -> 32), then halve until it
        divides ``smax`` — so a non-power-of-two request degrades to the
        nearest sensible block, never to 1-token blocks.  Shared with
        anything that sizes an arena before constructing the engine
        (benchmarks/batch_throughput.py)."""
        bs = max(1, min(block_size, smax))
        bs = 1 << (bs.bit_length() - 1)
        while smax % bs:
            bs //= 2
        return bs

    def _jit(self, name, fn, donate_argnums=None):
        """Per-engine jit cache.  ``donate_argnums`` marks pool args whose
        buffers XLA may update in place (the commit path donates the pool so
        committing moves lanes instead of copying the pool)."""
        if name not in self._jit_cache:
            kw = {} if donate_argnums is None else {"donate_argnums": donate_argnums}
            self._jit_cache[name] = jax.jit(fn, **kw)
        return self._jit_cache[name]

    def jit_compile_count(self) -> int:
        """Compiled signatures across this engine's jit cache — the cold-start
        compile budget bench_smoke.sh gates."""
        return sum(_compiled_signatures(fn) for fn in self._jit_cache.values())

    def _stage(self, name, shape, dtype, fill=0):
        """Reusable host staging buffer for per-step index arrays
        (serve_step.StagingBuffers) — keeps the per-step H2D traffic at a
        handful of small, allocation-free index arrays.  The synchronous
        engine runs one bank (every phase ends with a blocking host read, so
        a buffer is consumed before it is refilled); the pipelined engine
        flips between two banks at each ``begin_step``."""
        return self._staging.get(name, shape, dtype, fill)

    def _scatter_rows(self, pool_cache, trims, rows, *, donate: bool):
        """Write per-row sub-caches back into a pool with ONE scatter call.

        ``trims`` are row-sized caches (concatenated along the stream axis)
        — so the write-back moves touched rows only, once, instead of one
        full-pool ``scatter_streams`` copy per length group.  Rows are
        padded to n_slots with repeats of the first row (identical values
        re-written to the same slot) so the call compiles once."""
        combined = trims[0] if len(trims) == 1 else concat_streams(trims)
        rows = list(rows)
        pad = self.n_slots - len(rows)
        if pad:
            filler = gather_streams(combined, [0] * pad)
            combined = concat_streams([combined, filler])
            rows = rows + [rows[0]] * pad
        name = "commit_scatter" if donate else "stage_scatter"
        fn = self._jit(name, scatter_streams, donate_argnums=0 if donate else None)
        return fn(pool_cache, combined, jnp.asarray(np.asarray(rows, np.int32)))

    def _warp(self, logits):
        return warp_logits(logits, self.sampling.temperature, self.sampling.top_p)

    def _recurrent(self, cfg) -> bool:
        return cfg.arch_type in RECURRENT

    @staticmethod
    def _pad_group(rows: list[int], toks: np.ndarray, width: int):
        """Pad a row group to a fixed width by repeating its first row, so
        grouped recurrent calls compile once per token-length instead of
        once per (length, group-size).  Pad rows process row 0's tokens and
        scatter row 0's (identical) result again — bitwise harmless."""
        pad = width - len(rows)
        rows_p = rows + [rows[0]] * pad
        toks_p = np.concatenate([toks, np.repeat(toks[:1], pad, axis=0)]) if pad else toks
        return rows_p, toks_p

    # ------------------------------------------------------------ requests ---

    def submit(self, prompt: list[int], max_new: int = 64, seed: int | None = None,
               action_hint=None) -> int:
        """Queue a request; it is admitted when a pool slot frees up.
        ``seed`` drives this stream's drafting/verification randomness — a
        single-stream ``SpeculativeEngine`` with ``EngineConfig(seed=seed)``
        emits the identical token sequence.  ``action_hint`` — the expected
        (K, L1, L2) selector action — is a scheduler-only hint: the sharded
        engine bin-packs on it; a single engine has one pool, so it accepts
        and ignores it (API parity lets callers hint unconditionally)."""
        del action_hint
        if not 1 <= len(prompt) < self.ecfg.max_cache:
            raise ValueError(
                f"prompt of {len(prompt)} tokens cannot fit a {self.ecfg.max_cache}-slot cache ring"
            )
        if self.paged:
            # mirror _admit's gate exactly: a prompt accepted here must be
            # admittable into an otherwise-empty arena
            need = self._admit_need(len(prompt))
            cap = min(p.total_blocks for p in self._paged_pools())
            if need > cap:
                raise ValueError(
                    f"prompt of {len(prompt)} tokens needs {need} blocks "
                    f"(context + one speculation bucket); the arena has {cap}"
                )
        if self._pending_next is not None and self.tpool.free_slots:
            # A begun-ahead step locked in its admission decisions without
            # this request, and a free row means those decisions could have
            # included it (with zero free rows admission is provably
            # unchanged, so the dispatched step is kept).  Stall-and-drain:
            #   * boundary evicted a stream -> the release stands, so
            #     replaying admission would see post-eviction rows the
            #     synchronous admit-before-evict order would not; retire
            #     the step instead (its events surface at the next step())
            #     and the request joins at the following boundary — the
            #     same boundary at which the synchronous engine, whose
            #     admission ran before the eviction freed anything, admits;
            #   * otherwise -> rewind the step (abort_step) so the next
            #     begin_step re-runs the identical boundary with this
            #     request queued, exactly as the synchronous engine would.
            pending, self._pending_next = self._pending_next, None
            if pending.boundary_evicted:
                self._drained_events.extend(
                    self.finish_step(pending, pipeline_ahead=False))
            else:
                self.abort_step(pending)
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(BatchRequest(rid, list(prompt), max_new,
                                       self.ecfg.seed if seed is None else seed))
        return rid

    def can_admit(self, prompt_len: int) -> bool:
        """Whether a fresh request of ``prompt_len`` tokens could be admitted
        at the NEXT scheduling boundary without queueing: a free pool row, an
        empty FIFO (admission is strictly in order), and — paged — enough
        free blocks for its context plus one speculation bucket.  Dead-tail
        reclamation is deliberately not counted: the scheduler routing on
        this probe (ShardedBatchedSpeculativeEngine) must not promise
        capacity that a resident stream's next step could take back."""
        if self.queue or not self.tpool.free_slots or not self.dpool.free_slots:
            return False
        if self.paged:
            need = self._admit_need(prompt_len)
            if any(p.free_blocks < need for p in self._paged_pools()):
                return False
        return True

    def _prefill_row(self, cfg, params, ctx, name: str):
        """Prefill a fresh 1-row per-stream cache with ``ctx`` tokens."""
        row = init_cache(cfg, 1, self.ecfg.max_cache, per_stream=True)
        if not ctx:
            return row, None
        T = len(ctx)
        if self._recurrent(cfg):
            fn = self._jit(f"{name}_prefill_{T}", partial(forward, cfg=cfg, mode="full"))
            _, row, ex = fn(params, tokens=jnp.asarray(np.asarray(ctx, np.int32)[None]), cache=row)
            return row, np.asarray(ex["hidden"][0, T - 1])
        # bucket the pad, but never past the ring: a padded pass longer than
        # smax would wrap and overwrite the committed prefix it just wrote
        Tp = min(_next_pow2(T), self.ecfg.max_cache)
        toks = np.zeros((1, Tp), np.int32)
        toks[0, :T] = ctx
        fn = self._jit(f"{name}_prefill_p{Tp}", partial(forward, cfg=cfg, mode="full"))
        _, row, ex = fn(params, tokens=jnp.asarray(toks), cache=row,
                        lens=jnp.asarray([T], jnp.int32))
        return row, np.asarray(ex["hidden"][0, T - 1])

    def _paged_pools(self) -> list[PagedCachePool]:
        return [p for p in (self.tpool, self.dpool) if isinstance(p, PagedCachePool)]

    def _admit_need(self, prompt_len: int) -> int:
        """Blocks a fresh stream must find free: its context plus one
        default-action speculation bucket (step-time pressure handles any
        selector-driven growth beyond that)."""
        _, _, _, tpad0 = self._bucket_actions(
            {0: (self.ecfg.K, self.ecfg.L1, self.ecfg.L2)})
        return min(-(-(prompt_len + tpad0) // self.block_size), self.max_blocks)

    def _admit(self):
        while self.queue and self.tpool.free_slots:
            req = self.queue[0]
            if self.paged:
                need = self._admit_need(len(req.prompt))
                short = [p for p in self._paged_pools() if p.free_blocks < need]
                if short:
                    # recycle resident streams' dead tails (blocks past the
                    # frontier a default-action step would write) before
                    # leaving the request queued
                    _, _, _, tpad0 = self._bucket_actions(
                        {0: (self.ecfg.K, self.ecfg.L1, self.ecfg.L2)})
                    keeps = {s: len(st["committed"]) - 1 + tpad0
                             for s, st in self.streams.items()}
                    for pool in short:
                        self.counters["blocks_reclaimed"] += pool.reclaim_tails(keeps)
                    short = [p for p in self._paged_pools() if p.free_blocks < need]
                if short:
                    if not self.streams:
                        raise RuntimeError(
                            f"request {req.rid} needs {need} free blocks but the "
                            f"empty pool only has {min(p.free_blocks for p in short)}"
                        )
                    self.counters["admit_blocked"] += 1
                    break  # FIFO: the head blocks the queue until blocks free up
            self.queue.pop(0)
            ctx = req.prompt[:-1]
            trow, h_p = self._prefill_row(self.tc, self.tp, ctx, "tgt")
            drow, h_q = self._prefill_row(self.dc, self.dp, ctx, "drf")
            slot = self.tpool.admit(trow, ctx_len=len(ctx))
            slot_d = self.dpool.admit(drow, ctx_len=len(ctx))
            assert slot == slot_d
            self._admit_seq += 1
            self.streams[slot] = {
                "rid": req.rid,
                "slot": slot,
                "seq": self._admit_seq,
                "rng": np.random.default_rng(req.seed),
                "max_new": req.max_new,
                "out": [],
                "committed": list(req.prompt),
                "pending": int(req.prompt[-1]),
                "draft_delta": [int(req.prompt[-1])],
                "h_prev_p": h_p if h_p is not None else np.zeros(self.tc.d_model, np.float32),
                "h_prev_q": h_q if h_q is not None else np.zeros(self.dc.d_model, np.float32),
                "p_prev": None,
                "q_prev": None,
                "done": False,
            }

    def _finish(self, slot: int, reason: str = "length"):
        st = self.streams.pop(slot)
        self.finished[st["rid"]] = {"tokens": st["out"][: st["max_new"]], "reason": reason}
        self.tpool.release(slot)
        self.dpool.release(slot)

    def choose_action(self, stream):
        if self.selector is None:
            return self.ecfg.K, self.ecfg.L1, self.ecfg.L2
        return self.selector(stream, self)

    # ------------------------------------------------------------ drafting ---

    def _ingest_deltas(self, active):
        """Advance the draft pool over each stream's newly committed tokens.
        Returns per-slot (q0 dist, draft hidden at the new root)."""
        q0, hq = {}, {}
        if self._recurrent(self.dc):
            groups = defaultdict(list)
            for s in active:
                groups[len(self.streams[s]["draft_delta"])].append(s)
            trims, all_rows = [], []
            for L, rows in sorted(groups.items()):
                toks = np.asarray([self.streams[s]["draft_delta"] for s in rows], np.int32)
                rows_p, toks_p = self._pad_group(rows, toks, self.n_slots)
                sub = gather_streams(self.dpool.cache, rows_p)
                fn = self._jit(f"drf_ing_g{L}", partial(forward, cfg=self.dc, mode="decode"))
                logits, sub, ex = fn(self.dp, tokens=jnp.asarray(toks_p), cache=sub)
                trims.append(gather_streams(sub, list(range(len(rows)))))
                all_rows.extend(rows)
                w = np.asarray(self._warp(logits))
                hid = np.asarray(ex["hidden"])
                for i, s in enumerate(rows):
                    q0[s] = w[i, L - 1]
                    hq[s] = hid[i, L - 1]
                self.counters["draft_calls"] += 1
                self.counters["draft_tokens"] += L * len(rows)
            # one write-back for every length group's rows — donated unless
            # the pipelined back frame still aliases the pre-step buffer
            self.dpool.cache = self._scatter_rows(self.dpool.cache, trims, all_rows,
                                                  donate=not self.dpool.frame_held)
        else:
            Dp = _next_pow2(max(len(self.streams[s]["draft_delta"]) for s in active))
            toks = self._stage("ing_toks", (self.n_slots, Dp), np.int32)
            lens = self._stage("ing_lens", (self.n_slots,), np.int32)
            for s in active:
                d = self.streams[s]["draft_delta"]
                toks[s, : len(d)] = d
                lens[s] = len(d)
            fn = self._jit(f"drf_ing_p{Dp}", make_pool_decode_step(self.dc))
            logits, cache, hidden = fn(self.dp, self.dpool.cache, jnp.asarray(toks),
                                       jnp.asarray(lens))
            self.dpool.cache = cache
            w = np.asarray(self._warp(logits))
            hid = np.asarray(hidden)
            for s in active:
                q0[s] = w[s, lens[s] - 1]
                hq[s] = hid[s, lens[s] - 1]
            self.counters["draft_calls"] += 1
            self.counters["draft_tokens"] += int(lens.sum())
        return q0, hq

    @staticmethod
    def _bucket_actions(acts) -> tuple[int, int, int, int]:
        """Pad the batch's (K, L1, L2) actions to power-of-two buckets.

        The single source of truth for the iteration's static shapes: the
        drafting passes, the tree pass (Tpad) and step()'s eviction bound
        all use these same component-wise maxima."""
        Km = max(a[0] for a in acts.values())
        L1m = max(a[1] for a in acts.values())
        L2m = max(a[2] for a in acts.values())
        L1p = _next_pow2(L1m) if L1m else 0
        L2p = _next_pow2(L2m) if L2m else 0
        Kp = _next_pow2(Km) if (L2p and Km) else 0
        return Kp, L1p, L2p, 1 + L1p + Kp * L2p

    def _frontiers(self, active, Tpad, Dp) -> dict[int, int]:
        """Per-row live slot frontier for this iteration: the tree pass
        writes Tpad slots from C-1 and the padded ingest Dp slots from C-d
        (trunk drafting and replay commits stay within the tree extent) —
        mirror of step()'s logical-capacity eviction bound."""
        out = {}
        for s in active:
            C = len(self.streams[s]["committed"])
            d = len(self.streams[s]["draft_delta"])
            out[s] = max(C - 1 + Tpad, C - d + Dp)
        return out

    def _ensure_pool_blocks(self, active, acts, Tpad, Dp) -> bool:
        """Map the blocks this step's writes need, in three stages:
        free-list allocation, dead-tail reclamation (blocks wholly past a
        row's frontier — e.g. mapped for an earlier, bigger speculation
        bucket that committed short), then LIFO stream eviction.  Mutates
        ``active``/``acts`` when it evicts; returns True if it did.

        Tpad/Dp are RE-BUCKETED after every eviction: removing the stream
        that drove the batch maxima shrinks every survivor's frontier, so
        one victim's departure must not cascade into further evictions the
        smaller buckets would have avoided."""
        evicted = False
        fr = self._frontiers(active, Tpad, Dp)
        while active:
            short = False
            for pool in self._paged_pools():
                need = sum(pool.missing_blocks(s, fr[s]) for s in active)
                if need > pool.free_blocks:
                    self.counters["blocks_reclaimed"] += pool.reclaim_tails(fr)
                    need = sum(pool.missing_blocks(s, fr[s]) for s in active)
                    if need > pool.free_blocks:
                        short = True
            if not short:
                break
            victim = max(active, key=lambda s: self.streams[s]["seq"])
            self.counters["evicted"] += 1
            self._finish(victim, reason="evicted:pool_blocks")
            active.remove(victim)
            del acts[victim]
            evicted = True
            if active:
                _, _, _, Tpad = self._bucket_actions(acts)
                Dp = _next_pow2(max(len(self.streams[s]["draft_delta"]) for s in active))
                fr = self._frontiers(active, Tpad, Dp)
            else:
                fr = {}
        for pool in self._paged_pools():
            assert pool.ensure_rows(fr), "free list exhausted after the pressure loop"
        if isinstance(self.tpool, PagedCachePool):
            # peak is the TARGET arena's occupancy (the HBM that matters);
            # the draft arena is a proportionally smaller mirror
            self.counters["blocks_peak"] = max(self.counters["blocks_peak"],
                                               self.tpool.used_blocks)
        return evicted

    def pool_occupancy(self) -> dict:
        """Arena occupancy (blocks used/free, fragmentation) per pool —
        surfaced by benchmarks/batch_throughput.py next to the commit
        counters.  Empty for non-paged engines."""
        fr = {s: len(st["committed"]) for s, st in self.streams.items()}
        out = {}
        for name, pool in (("target", self.tpool), ("draft", self.dpool)):
            if isinstance(pool, PagedCachePool):
                out[name] = pool.occupancy(fr)
        return out

    def _draft_trees(self, active, acts, q0, pads):
        """Lockstep-draft every stream's (K, L1, L2) delayed tree on a local
        copy of the draft pool (discarded after, like the single engine)."""
        Kp, L1p, L2p, Tpad = pads
        # loop trip counts are host-side, not compiled shapes: iterate to the
        # raw batch maxima (the bucketed L1p/L2p only size the tree pass)
        L1m = max(a[1] for a in acts.values())
        L2m = max(a[2] for a in acts.values())
        dwork = self.dpool.cache
        cur = dict(q0)
        trunk_tok = {s: [] for s in active}
        trunk_q = {s: [] for s in active}
        step_fn = self._jit("drf_step", make_pool_locked_step(self.dc))
        for j in range(L1m):
            toks = np.zeros((self.n_slots, 1), np.int32)
            keep = np.zeros((self.n_slots,), bool)
            n_live = 0
            for s in active:
                if j < acts[s][1]:
                    t = draw_token(self.streams[s]["rng"], cur[s])
                    toks[s, 0] = t
                    keep[s] = True
                    trunk_tok[s].append(t)
                    n_live += 1
            logits, dwork = step_fn(self.dp, dwork, jnp.asarray(toks), jnp.asarray(keep))
            w = np.asarray(self._warp(logits[:, 0]))
            for s in active:
                if keep[s]:
                    cur[s] = w[s]
                    trunk_q[s].append(w[s])
            self.counters["draft_calls"] += 1
            self.counters["draft_tokens"] += n_live

        branch_tok = {s: [[] for _ in range(acts[s][0])] for s in active}
        branch_q = {s: [[] for _ in range(acts[s][0])] for s in active}
        if Kp and L2p:
            dfork = fork_streams(dwork, Kp)
            V = self.tc.vocab
            curb = np.zeros((self.n_slots * Kp, V), np.float32)
            for s in active:
                for k in range(acts[s][0]):
                    curb[s * Kp + k] = cur[s]
            bstep = self._jit(f"drf_bstep_k{Kp}", partial(forward, cfg=self.dc, mode="decode"))
            for j in range(L2m):
                toks = np.zeros((self.n_slots * Kp, 1), np.int32)
                n_live = 0
                for s in active:
                    K, _, L2 = acts[s]
                    if j < L2:
                        for k in range(K):
                            t = draw_token(self.streams[s]["rng"], curb[s * Kp + k])
                            toks[s * Kp + k, 0] = t
                            branch_tok[s][k].append(t)
                            n_live += 1
                logits, dfork, _ = bstep(self.dp, tokens=jnp.asarray(toks), cache=dfork)
                w = np.asarray(self._warp(logits[:, 0]))
                for s in active:
                    K, _, L2 = acts[s]
                    if j < L2:
                        for k in range(K):
                            curb[s * Kp + k] = w[s * Kp + k]
                            branch_q[s][k].append(w[s * Kp + k])
                self.counters["draft_calls"] += 1
                self.counters["draft_tokens"] += n_live

        trees = {}
        for s in active:
            K, L1, L2 = acts[s]
            tokens, parent, depth, pid, qs = [-1], [-1], [0], [0], [q0[s]]
            node = 0
            for j in range(L1):
                tokens.append(trunk_tok[s][j])
                parent.append(node)
                depth.append(depth[node] + 1)
                pid.append(0)
                qs.append(trunk_q[s][j])
                node = len(tokens) - 1
            branch_nodes = [node] * K
            for j in range(L2):
                for k in range(K):
                    tokens.append(branch_tok[s][k][j])
                    parent.append(branch_nodes[k])
                    depth.append(depth[branch_nodes[k]] + 1)
                    pid.append(k)
                    qs.append(branch_q[s][k][j])
                    branch_nodes[k] = len(tokens) - 1
            trees[s] = DraftTree(
                tokens=np.asarray(tokens, np.int64),
                parent=np.asarray(parent, np.int64),
                depth=np.asarray(depth, np.int64),
                q=np.stack(qs),
                path_id=np.asarray(pid, np.int64),
            )
        return trees

    # ----------------------------------------------------- target: tree -----

    def _target_tree_dispatch(self, active, trees, Tpad):
        """Dispatch ONE padded tree-masked target pass over every active row
        and return its warped logits / hidden states as DEVICE arrays (with
        async host copies kicked off) — the futures ``finish_step`` blocks
        on, so the host is free between dispatch and verification.

        The host ships (B, Tpad) token and parent-pointer index arrays only:
        ancestor masks are composed on device (device_ancestor_mask) and the
        idle-row freeze happens inside the same jit call — no per-iteration
        (B, Tpad, Tpad) mask tensor is rebuilt or transferred."""
        ttoks = self._stage("tree_toks", (self.n_slots, Tpad), np.int32)
        parents = self._stage("tree_parents", (self.n_slots, Tpad), np.int32, fill=-1)
        keep = self._stage("tree_keep", (self.n_slots,), np.bool_, fill=False)
        for s in active:
            tree = trees[s]
            n = tree.n_nodes
            ttoks[s, :n] = tree.tokens
            ttoks[s, 0] = self.streams[s]["pending"]
            parents[s, :n] = tree.parent
            keep[s] = True
        fn = self._jit(f"tgt_tree_p{Tpad}", make_pool_tree_step(self.tc),
                       donate_argnums=1)
        logits, cache, hidden = fn(self.tp, self.tpool.cache, jnp.asarray(ttoks),
                                   jnp.asarray(parents), jnp.asarray(keep))
        self.tpool.cache = cache
        real = sum(trees[s].n_nodes for s in active)
        self.counters["target_calls"] += 1
        self.counters["target_tokens"] += real
        self.counters["tree_lanes_total"] += self.n_slots * Tpad
        self.counters["pad_nodes_total"] += self.n_slots * Tpad - real
        p_dev = self._warp(logits)
        for arr in (p_dev, hidden):
            start_copy = getattr(arr, "copy_to_host_async", None)
            if start_copy is not None:
                start_copy()
        return p_dev, hidden

    def _ragged_layout(self, active, trees):
        """Per-stream (offset, n_nodes) segments in the flat node buffer,
        and its bucketed total Npad.  Offsets advance by the aligned segment
        size (pallas: 8, so Q tiles stay owner-uniform); Npad buckets to the
        next power of two so the jit cache stays bounded exactly like the
        padded path's Tpad buckets."""
        align = self._ragged_align
        offs, off = {}, 0
        for s in active:
            n = trees[s].n_nodes
            offs[s] = (off, n)
            off += -(-n // align) * align
        return offs, _next_pow2(max(off, align))

    def _target_tree_dispatch_ragged(self, active, trees, roffs):
        """Ragged counterpart of ``_target_tree_dispatch``: ONE flat
        node-major tree pass over every active stream's tree, no per-row
        padding to the pool-wide Tpad (serve_step.make_pool_ragged_tree_step;
        docs/serving.md "Ragged node-major tree batching").  The host ships
        (Npad,) token/owner/parent/depth/local arrays plus (B,) counts —
        the same small-index-arrays contract as the padded dispatch, with
        identical async-host-copy futures returned."""
        offs, Npad = roffs
        toks = self._stage("rtree_toks", (Npad,), np.int32)
        owner = self._stage("rtree_owner", (Npad,), np.int32)
        parent = self._stage("rtree_parent", (Npad,), np.int32, fill=-1)
        depth = self._stage("rtree_depth", (Npad,), np.int32)
        local = self._stage("rtree_local", (Npad,), np.int32, fill=-1)
        counts = self._stage("rtree_counts", (self.n_slots,), np.int32)
        align = self._ragged_align
        for s in active:
            o, n = offs[s]
            tree = trees[s]
            toks[o:o + n] = tree.tokens
            toks[o] = self.streams[s]["pending"]
            parent[o:o + n] = np.where(tree.parent >= 0, o + tree.parent, -1)
            depth[o:o + n] = tree.depth
            local[o:o + n] = np.arange(n)
            # owner covers the FULL aligned segment: alignment-gap lanes keep
            # local = -1 (they write nothing, attend to nothing) but carry
            # the segment's owner so pallas Q tiles stay owner-uniform
            owner[o:o + (-(-n // align) * align)] = s
            counts[s] = n
        fn = self._jit(f"tgt_rtree_n{Npad}", make_pool_ragged_tree_step(self.tc),
                       donate_argnums=1)
        logits, cache, hidden = fn(self.tp, self.tpool.cache, jnp.asarray(toks),
                                   jnp.asarray(owner), jnp.asarray(parent),
                                   jnp.asarray(depth), jnp.asarray(local),
                                   jnp.asarray(counts))
        self.tpool.cache = cache
        real = sum(trees[s].n_nodes for s in active)
        self.counters["target_calls"] += 1
        self.counters["target_tokens"] += real
        self.counters["tree_lanes_total"] += Npad
        self.counters["pad_nodes_total"] += Npad - real
        p_dev = self._warp(logits)
        for arr in (p_dev, hidden):
            start_copy = getattr(arr, "copy_to_host_async", None)
            if start_copy is not None:
                start_copy()
        return p_dev, hidden

    def _commit_tables(self, active, node_paths):
        """Stage the fused commit's index tables (accepted node paths, path
        lengths, pre-block committed lengths, active mask) and return them
        with the padded path width P.  Shared between the single-engine
        commit and the sharded engine's grouped cross-shard commit."""
        B = self.n_slots
        P = _next_pow2(max([len(node_paths[s]) for s in active] + [1]))
        npath = self._stage("commit_path", (B, P), np.int32)
        plen = self._stage("commit_plen", (B,), np.int32)
        Cb = self._stage("commit_C", (B,), np.int32)
        act = self._stage("commit_act", (B,), np.bool_, fill=False)
        for s in active:
            path = node_paths[s]
            npath[s, : len(path)] = path
            plen[s] = len(path)
            Cb[s] = len(self.streams[s]["committed"]) - 1
            act[s] = True
        return npath, plen, Cb, act, P

    def _commit_tree_batch(self, active, node_paths, Tpad):
        """Fused commit: ONE jitted, pool-donating call re-compacts every
        active row's accepted path (serve_step.make_pool_commit_step) —
        the tentpole replacing the per-stream eager ``.at[].set`` chains
        (kept as serve_step.commit_row_reference, the test/bench oracle)."""
        npath, plen, Cb, act, P = self._commit_tables(active, node_paths)
        fn = self._jit(f"commit_T{Tpad}_P{P}",
                       make_pool_commit_step(self.tc, Tpad), donate_argnums=0)
        t0 = time.perf_counter()
        self.tpool.cache = fn(self.tpool.cache, jnp.asarray(npath), jnp.asarray(plen),
                              jnp.asarray(Cb), jnp.asarray(act))
        if self.profile_commits:
            jax.block_until_ready(self.tpool.cache)
        self.counters["commit_calls"] += 1
        self.counters["commit_ms"] += (time.perf_counter() - t0) * 1e3

    # --------------------------------------------------- target: replay -----

    def _target_replay(self, active, trees, acts, Kp):
        """Recurrent targets: grouped trunk decode + forked branch replay.
        Returns (snapshot, per-slot p matrices) ready for verification.

        p matrices are float32 (the warped logits' native dtype) and cast to
        float64 only at the verifier boundary in step() — no dense float64
        (n_nodes, vocab) allocations per stream per step."""
        snapshot = self.tpool.cache
        structs = {s: delayed_structure(trees[s]) for s in active}
        p_host = {s: np.zeros((trees[s].n_nodes, trees[s].vocab), np.float32)
                  for s in active}
        groups = defaultdict(list)
        for s in active:
            trunk, _, _ = structs[s]
            groups[1 + len(trunk)].append(s)
        trims, trunk_rows = [], []
        for L, rows in sorted(groups.items()):
            toks = np.zeros((len(rows), L), np.int32)
            for i, s in enumerate(rows):
                trunk, _, _ = structs[s]
                toks[i, 0] = self.streams[s]["pending"]
                for j, v in enumerate(trunk):
                    toks[i, 1 + j] = int(trees[s].tokens[v])
            rows_p, toks_p = self._pad_group(rows, toks, self.n_slots)
            sub = gather_streams(snapshot, rows_p)
            fn = self._jit(f"tgt_trunk_g{L}", partial(forward, cfg=self.tc, mode="decode"))
            logits, sub, _ = fn(self.tp, tokens=jnp.asarray(toks_p), cache=sub)
            trims.append(gather_streams(sub, list(range(len(rows)))))
            trunk_rows.extend(rows)
            w = np.asarray(self._warp(logits))
            for i, s in enumerate(rows):
                trunk, _, _ = structs[s]
                p_host[s][0] = w[i, 0]
                for j, v in enumerate(trunk):
                    p_host[s][v] = w[i, 1 + j]
            self.counters["target_calls"] += 1
            self.counters["target_tokens"] += L * len(rows)
        # one write-back of all trunk-advanced rows (snapshot stays intact —
        # it is the commit checkpoint)
        work = self._scatter_rows(snapshot, trims, trunk_rows, donate=False)

        has_branches = [s for s in active if structs[s][2]]
        if has_branches and Kp:
            fork = fork_streams(work, Kp)
            bgroups = defaultdict(list)
            for s in has_branches:
                _, _, branches = structs[s]
                bgroups[len(branches[0])].append(s)
            for L2, rows in sorted(bgroups.items()):
                frows, meta = [], []
                for s in rows:
                    _, _, branches = structs[s]
                    for k, path in enumerate(branches):
                        frows.append(s * Kp + k)
                        meta.append((s, path))
                btoks = np.asarray(
                    [[int(trees[s].tokens[v]) for v in path] for s, path in meta], np.int32
                )
                frows_p, btoks_p = self._pad_group(frows, btoks, self.n_slots * Kp)
                sub = gather_streams(fork, frows_p)
                fn = self._jit(f"tgt_branch_g{L2}k{Kp}", partial(forward, cfg=self.tc, mode="decode"))
                logits, _, _ = fn(self.tp, tokens=jnp.asarray(btoks_p), cache=sub)
                pb = np.asarray(self._warp(logits))
                for i, (s, path) in enumerate(meta):
                    for j, v in enumerate(path):
                        p_host[s][v] = pb[i, j]
                self.counters["target_calls"] += 1
                self.counters["target_tokens"] += L2 * len(frows)
        return snapshot, p_host

    def _commit_replay(self, active, snapshot, accepted_by_slot):
        """Restore the checkpoint and re-advance each stream along
        [root] + accepted (grouped by commit length), then write every row
        back with ONE donated scatter — the replay strategy's single fused
        commit write per step."""
        hid_last = {}
        groups = defaultdict(list)
        for s in active:
            groups[1 + len(accepted_by_slot[s])].append(s)
        trims, all_rows = [], []
        for L, rows in sorted(groups.items()):
            toks = np.zeros((len(rows), L), np.int32)
            for i, s in enumerate(rows):
                toks[i, 0] = self.streams[s]["pending"]
                for j, t in enumerate(accepted_by_slot[s]):
                    toks[i, 1 + j] = int(t)
            rows_p, toks_p = self._pad_group(rows, toks, self.n_slots)
            sub = gather_streams(snapshot, rows_p)
            fn = self._jit(f"tgt_commit_g{L}", partial(forward, cfg=self.tc, mode="decode"))
            _, sub, ex = fn(self.tp, tokens=jnp.asarray(toks_p), cache=sub)
            trims.append(gather_streams(sub, list(range(len(rows)))))
            all_rows.extend(rows)
            hid = np.asarray(ex["hidden"])
            for i, s in enumerate(rows):
                hid_last[s] = hid[i, L - 1]
        t0 = time.perf_counter()
        self.tpool.cache = self._scatter_rows(snapshot, trims, all_rows, donate=True)
        if self.profile_commits:
            jax.block_until_ready(self.tpool.cache)
        self.counters["commit_calls"] += 1
        self.counters["commit_ms"] += (time.perf_counter() - t0) * 1e3
        return hid_last

    # ---------------------------------------------------------------- step ---

    def begin_step(self) -> PendingStep | None:
        """The DISPATCH half of a step: run the scheduling boundary (admit
        queued requests, capacity-evict, map paged blocks), then dispatch
        the draft ingest, the delayed-tree drafting and the tree-masked
        target pass.  Returns a ``PendingStep`` whose tree-pass outputs are
        device futures (tree strategy), or None when nothing is active.

        ALL admission/eviction/block-pressure decisions happen here, at the
        pipeline boundary — never between a dispatch and its verification —
        which is what lets the pipelined driver overlap ``finish_step``'s
        host tail with the next step's device work without perturbing
        scheduling (the exactness argument in docs/serving.md)."""
        self._staging.flip()
        self._admit()
        active = [s for s in sorted(self.streams) if not self.streams[s]["done"]]
        if not active:
            return None
        acts = {s: tuple(self.choose_action(self.streams[s])) for s in active}
        # eviction: a stream whose ring cannot hold another padded speculation
        # block (the tree pass writes Tpad slots from the batch-maxima
        # buckets) or the padded ingest width must finish instead of wrapping
        # the ring onto committed slots.
        _, _, _, Tpad = self._bucket_actions(acts)
        Dp = _next_pow2(max(len(self.streams[s]["draft_delta"]) for s in active))
        smax = self.ecfg.max_cache
        boundary_evicted = False
        for s in list(active):
            C = len(self.streams[s]["committed"])
            d = len(self.streams[s]["draft_delta"])
            # tree pass writes Tpad slots from C-1; padded ingest writes Dp
            # slots from the draft length C-d — either wrapping onto live
            # slots would corrupt the committed prefix
            if C - 1 + Tpad > smax or C - d + Dp > smax:
                self.counters["evicted"] += 1
                self._finish(s, reason="evicted:cache_full")
                active.remove(s)
                del acts[s]
                boundary_evicted = True
        if not active:
            return None
        # re-bucket: eviction can only shrink the maxima, never grow them
        pads = self._bucket_actions(acts)
        Kp, L1p, L2p, Tpad = pads
        if self.paged:
            # map every block this iteration's writes will touch; under
            # pressure reclaim dead tails first, evict (LIFO) only as a
            # last resort
            Dp = _next_pow2(max(len(self.streams[s]["draft_delta"]) for s in active))
            if self._ensure_pool_blocks(active, acts, Tpad, Dp):
                boundary_evicted = True
                if not active:
                    return None
                pads = self._bucket_actions(acts)
                Kp, L1p, L2p, Tpad = pads
        # rewind coordinates (pipelined mode): abort_step can restore
        # rng/draft state as if the step never began
        C0 = {s: len(self.streams[s]["committed"]) - 1 for s in active}
        rng_state, D0 = None, None
        if self.pipeline:
            # numpy's .state property builds a fresh dict per access, so the
            # snapshot needs no deepcopy
            rng_state = {s: self.streams[s]["rng"].bit_generator.state
                         for s in active}
            if self._recurrent(self.dc):
                # recurrent draft state integrates every token — it can only
                # be rewound from a saved copy, so hold the back frame
                self.dpool.begin_frame()
            else:
                # attention draft rewind is LOGICAL: this step's only pool
                # mutation is the append-only, deterministic delta ingest
                # (trunk drafting runs on a discarded local copy), so
                # abort_step erases pos >= D0 lanes and the re-begun step
                # re-ingests bit-identical values.  No back frame held:
                # keeping the pre-step arena alive serialized the allocator
                # and cost more than the pipeline overlap earned.
                D0 = {s: len(self.streams[s]["committed"])
                         - len(self.streams[s]["draft_delta"])
                      for s in active}
        q0, hq = self._ingest_deltas(active)
        trees = self._draft_trees(active, acts, q0, pads)
        if self.strategy == "tree":
            roffs = None
            if self._ragged_ok:
                offs, Npad = self._ragged_layout(active, trees)
                # auto mode goes ragged only on a STRICT lane win (drain
                # tails, heterogeneous actions); a full homogeneous pool
                # where Npad == n_slots * Tpad keeps the padded layout
                if self.ragged == "always" or Npad < self.n_slots * Tpad:
                    roffs = (offs, Npad)
            if roffs is not None:
                p_dev, hid_dev = self._target_tree_dispatch_ragged(
                    active, trees, roffs)
            else:
                p_dev, hid_dev = self._target_tree_dispatch(active, trees, Tpad)
            return PendingStep(active=active, acts=acts, pads=pads, trees=trees,
                               hq=hq, C0=C0, p_dev=p_dev, hid_dev=hid_dev,
                               rng_state=rng_state, D0=D0, roffs=roffs,
                               boundary_evicted=boundary_evicted)
        snapshot, p_host = self._target_replay(active, trees, acts, Kp)
        return PendingStep(active=active, acts=acts, pads=pads, trees=trees,
                           hq=hq, C0=C0, snapshot=snapshot, p_host=p_host,
                           rng_state=rng_state, D0=D0,
                           boundary_evicted=boundary_evicted)

    def verify_step(self, pending: PendingStep) -> VerifiedStep:
        """The VERIFY phase: block on the tree-pass logits future and run
        every stream's host-side accept/reject walk.  Consumes per-stream
        rng, so it fixes this step's tokens — but touches no pool state and
        no scheduling state, which is what lets the sharded driver verify
        one shard while the other shards' dispatched device work is still
        in flight, then batch all commits into one call."""
        if self.dpool.frame_held:
            self.dpool.drop_frame()  # committing to this step: no rewind past here
        active, trees = pending.active, pending.trees
        accepted, corr = {}, {}
        if self.strategy == "tree":
            p_all = np.asarray(pending.p_dev)
            node_paths = {}
            for s in active:
                tree = trees[s]
                if pending.roffs is not None:
                    o, n = pending.roffs[0][s]
                    tree.p = to_verifier_dtype(p_all[o:o + n])
                else:
                    tree.p = to_verifier_dtype(p_all[s, : tree.n_nodes])
                acc, c = verify_tree(tree, self.ecfg.verifier, self.streams[s]["rng"])
                accepted[s], corr[s] = acc, int(c)
                node_paths[s] = SpeculativeEngine._accepted_nodes(tree, acc)
            return VerifiedStep(pending, accepted, corr, node_paths=node_paths)
        for s in active:
            tree = trees[s]
            tree.p = to_verifier_dtype(pending.p_host[s])
            acc, c = verify_tree(tree, self.ecfg.verifier, self.streams[s]["rng"])
            accepted[s], corr[s] = acc, int(c)
        return VerifiedStep(pending, accepted, corr)

    def commit_step(self, v: VerifiedStep) -> None:
        """The COMMIT phase: ONE fused, pool-donating call compacts every
        row's accepted path (tree strategy), or the grouped replay
        re-advance (replay strategy, which also yields the last hidden
        states).  Must run before ``retire_step`` extends ``committed`` —
        the commit indices are relative to the pre-block length."""
        pending = v.pending
        if self.strategy == "tree":
            self._commit_tree_batch(pending.active, v.node_paths, pending.pads[3])
        else:
            v.hid_last = self._commit_replay(pending.active, pending.snapshot,
                                             v.accepted)

    def _read_hidden(self, v: VerifiedStep) -> None:
        """Publish each stream's last accepted hidden state (``h_prev_p``).
        On the tree strategy this blocks on the hidden-state device future,
        so ``retire_step`` defers it behind the pipeline-ahead dispatch
        whenever nothing reads it at the next boundary — after which a
        stream may already be gone (the begun-ahead boundary can evict), so
        departed rows are skipped."""
        pending = v.pending
        if self.strategy == "tree":
            hid_all = np.asarray(pending.hid_dev)
            for s in pending.active:
                if s not in self.streams:
                    continue
                path = v.node_paths[s]
                idx = path[-1] if path else 0
                if pending.roffs is not None:
                    self.streams[s]["h_prev_p"] = hid_all[pending.roffs[0][s][0] + idx]
                else:
                    self.streams[s]["h_prev_p"] = hid_all[s, idx]
        else:
            for s in pending.active:
                if s in self.streams:
                    self.streams[s]["h_prev_p"] = v.hid_last[s]

    def retire_step(self, v: VerifiedStep, pipeline_ahead: bool | None = None) -> list[dict]:
        """The RETIRE phase: token bookkeeping, the pipeline-ahead decision,
        then the host tail (hidden-state readback, releasing finished
        streams' rows/blocks).

        In pipelined mode (``pipeline_ahead`` defaults to ``self.pipeline``)
        the critical bookkeeping runs first — the stream fields the next
        boundary reads (``committed``, ``pending``, ``draft_delta``,
        ``done``) and the release of retiring streams' rows/blocks — then
        the next step is begun, then the host tail (the blocking
        hidden-state readback, deferred only when no selector consumes it
        at the next boundary) runs while the device already chews on step
        i+1.  Releasing BEFORE the begun-ahead boundary is what lets the
        pipeline run ahead across retiring iterations: the boundary sees
        exactly the post-release pool the synchronous engine's next
        ``begin_step`` would see, so admission and pressure decisions — and
        therefore tokens — stay identical.  The pipeline stalls only when
        the boundary itself comes up empty (nothing left to dispatch)."""
        pending = v.pending
        retire: list[tuple[int, dict]] = []
        for s in pending.active:
            node_path = None if v.node_paths is None else v.node_paths[s]
            retire.append(
                (s, self._advance_stream(s, pending.trees[s], v.accepted[s],
                                         v.corr[s], pending.hq[s], node_path))
            )
        if pipeline_ahead is None:
            pipeline_ahead = self.pipeline
        # defer the blocking hidden readback past the next dispatch only
        # when nothing at the next boundary consumes it (selectors read
        # h_prev_p); the replay strategy's hid_last is already host-side
        defer_hid = (pipeline_ahead and self.strategy == "tree"
                     and self.selector is None)
        if not defer_hid:
            self._read_hidden(v)
        # release finished streams' rows/blocks BEFORE the next boundary —
        # the freed capacity is scheduling-visible there (admission and
        # block pressure), exactly as after a synchronous step
        for s, ev in retire:
            if ev["done"]:
                self._finish(s)
        if pipeline_ahead:
            assert self._pending_next is None, "a begun-ahead step is already pending"
            self.counters["pipeline_iterations"] += 1
            self._pending_next = self.begin_step()
            if self._pending_next is not None:
                self.counters["pipeline_ahead"] += 1
            else:
                # an empty boundary (no live streams, nothing admissible)
                # is the only stall left: ahead + stalls == iterations
                self.counters["pipeline_stalls"] += 1
        # host tail: runs behind step i+1's dispatched device work
        if defer_hid:
            self._read_hidden(v)
        return [ev for _, ev in retire]

    def finish_step(self, pending: PendingStep, pipeline_ahead: bool | None = None) -> list[dict]:
        """Verify + commit + retire a dispatched step — the single-engine
        composition of the three phases (the sharded engine drives them
        separately to interleave its shards)."""
        v = self.verify_step(pending)
        self.commit_step(v)
        return self.retire_step(v, pipeline_ahead)

    def step(self) -> list[dict]:
        """Admit queued requests, advance every active stream one speculative
        block, and return per-request progress events.  Synchronous form of
        begin_step + finish_step; in pipelined mode it first consumes the
        step begun ahead by the previous ``finish_step`` (and surfaces any
        events a mid-run ``submit`` retired on its behalf)."""
        events, self._drained_events = self._drained_events, []
        pending, self._pending_next = self._pending_next, None
        if pending is None:
            pending = self.begin_step()
        if pending is None:
            return events
        return events + self.finish_step(pending)

    def drain_pipeline(self) -> list[dict]:
        """Finish the begun-ahead step WITHOUT beginning another — the drain
        half of the stall-and-drain rule.  Call before out-of-band pool or
        scheduling mutations (or at shutdown) so no dispatched work is left
        in flight.  No-op (returns []) when nothing is pending."""
        pending, self._pending_next = self._pending_next, None
        if pending is None:
            return []
        return self.finish_step(pending, pipeline_ahead=False)

    def abort_step(self, pending: PendingStep) -> None:
        """Rewind a begun step as if it never dispatched (pipelined mode):
        restore every active stream's rng snapshot, rewind the draft pool —
        logically for attention-family drafts (the step's only draft-pool
        mutation is the append-only delta ingest: erase pos >= D0 lanes
        with ``invalidate_from`` and the re-begun step re-ingests identical
        values), from the double-buffered back frame for recurrent drafts —
        and invalidate the target rows' speculative tree writes (their pool
        buffer was donated, so the pre-pass buffer is gone — but every
        speculative lane carries pos >= C0 and is erased by
        ``CachePool.invalidate_from``; the replay strategy never touches the
        target pool before its commit).  Boundary decisions taken by
        ``begin_step`` (admissions, evictions, block mappings) are
        scheduling events that stand; dead mappings are recycled by the
        normal pressure path.  Work counters also stand — they count
        dispatched work."""
        assert pending.rng_state is not None, \
            "abort_step needs the rng snapshots only pipelined begin_step records"
        if pending is self._pending_next:
            self._pending_next = None
        for s, state in pending.rng_state.items():
            if s in self.streams:
                self.streams[s]["rng"].bit_generator.state = state
        if self.dpool.frame_held:
            self.dpool.rollback_frame()
        elif pending.D0 is not None:
            self.dpool.invalidate_from({s: pending.D0[s] for s in pending.active
                                        if s in self.streams})
        if self.strategy == "tree":
            self.tpool.invalidate_from({s: pending.C0[s] for s in pending.active
                                        if s in self.streams})

    def abort_pipeline(self) -> int:
        """Rewind the begun-ahead step, if any (``abort_step`` on
        ``_pending_next``).  Returns the number of steps rewound (0 or 1) —
        the sharded engine sums it across shards."""
        pending, self._pending_next = self._pending_next, None
        if pending is None:
            return 0
        self.abort_step(pending)
        return 1

    def _advance_stream(self, slot, tree, accepted, corr, h_q, node_path=None):
        """Token bookkeeping shared with SpeculativeEngine.step.  Marks the
        stream done when it reaches ``max_new`` but does NOT release its pool
        row — ``finish_step``'s retirement tail owns that, after the
        pipeline-ahead decision."""
        st = self.streams[slot]
        nodes = (
            node_path if node_path is not None
            else SpeculativeEngine._accepted_nodes(tree, accepted)
        )
        st["p_prev"] = tree.p[nodes[-1]] if accepted else tree.p[0]
        st["q_prev"] = tree.q[nodes[-1]] if accepted else tree.q[0]
        new_tokens = list(accepted) + [corr]
        st["committed"].extend(new_tokens)
        st["pending"] = corr
        st["draft_delta"] = new_tokens
        st["h_prev_q"] = h_q
        st["out"].extend(new_tokens)
        self.counters["accepted"] += len(accepted)
        self.counters["blocks"] += 1
        ev = {"rid": st["rid"], "new_tokens": new_tokens,
              "done": len(st["out"]) >= st["max_new"]}
        if ev["done"]:
            st["done"] = True
        return ev

    # ------------------------------------------------------ distribution peeks

    def _peek(self, cfg, params, pool, slot: int, toks: list[int], name: str):
        """Score ``toks`` against one pool row WITHOUT mutating the pool:
        gather the row to a dense 1-row cache (paged rows come back dense),
        decode, discard the advanced copy.  The pooled form of the
        single-stream peek oracles — compiled once per token-length bucket."""
        sub = gather_streams(pool.cache, [slot])
        T = len(toks)
        fn = self._jit(f"{name}_peek_{T}", partial(forward, cfg=cfg, mode="decode"))
        logits, _, _ = fn(params, tokens=jnp.asarray(np.asarray(toks, np.int32)[None]),
                          cache=sub)
        return np.asarray(self._warp(logits[0]))[-1]

    def peek_draft_dist(self, stream, ctx: list[int]) -> np.ndarray:
        """q(. | committed + ctx) for a pooled stream, functional.

        With the single-stream peeks this unblocks AnalyticSelector under
        continuous batching (the ROADMAP "Batched analytic selector" item).
        Note the selector itself draws from its OWN rng, shared across the
        streams it serves — its decisions are deterministic per arrival
        order, but not reproduced by independent single-stream runs."""
        toks = list(stream["draft_delta"]) + list(ctx)
        return self._peek(self.dc, self.dp, self.dpool, stream["slot"], toks, "drf")

    def peek_target_dist(self, stream, ctx: list[int]) -> np.ndarray:
        """p(. | committed + ctx) for a pooled stream, functional."""
        toks = [stream["pending"]] + list(ctx)
        return self._peek(self.tc, self.tp, self.tpool, stream["slot"], toks, "tgt")

    # ----------------------------------------------------------------- run ---

    def run(self) -> dict[int, dict]:
        """Drain the queue: step until every submitted request finished.

        Returns ``{rid: {"tokens", "reason"}}`` for the requests completed by
        this call, removing them from the engine — a long-lived serving loop
        does not accumulate finished payloads, and repeated calls never
        re-return stale results."""
        done: dict[int, dict] = {}

        def drain():
            while self.finished:
                rid, info = self.finished.popitem()
                done[rid] = info

        drain()
        while self.queue or self.streams:
            before = len(done)
            self.step()
            drain()
            if not self.streams and not self.queue:
                break
            assert self.streams or len(done) > before, "scheduler stalled"
        return done

    def generate_batch(self, prompts, max_new: int = 32, seeds=None) -> list[list[int]]:
        """Convenience: submit all prompts, drain, return outputs in order."""
        rids = [
            self.submit(p, max_new, None if seeds is None else seeds[i])
            for i, p in enumerate(prompts)
        ]
        out = self.run()
        return [out[r]["tokens"] for r in rids]


class ShardedBatchedSpeculativeEngine:
    """Stream axis sharded across a data mesh: the continuous-batching pool
    split into ``data_shards`` contiguous slot shards, each an independent
    ``BatchedSpeculativeEngine`` over its own rows and (paged) its own
    private block arena — shard-local free lists, host-mirrored
    pos/len/block tables, admission FIFO, pressure reclamation and
    eviction — with every shard's pool arrays NamedSharding-committed to
    its slice of the mesh data axis (launch/mesh.shard_meshes;
    launch/sharding.pool_shardings).  On a multi-device host the shards'
    pool steps dispatch onto distinct devices and overlap; on one device
    they serialize but stay token-identical (the host-local smoke path).

    The only cross-shard state is the scheduler: ``submit()`` routes each
    request to a shard that can admit it now (``can_admit`` — free row,
    empty FIFO, free blocks), bin-packing on the request's expected
    selector action first (``_pack_cost``: streams with similar (K, L1, L2)
    buckets land co-resident so shard-local Tpad buckets stay tight —
    docs/serving.md "Selector-aware bin-packing"), breaking cost ties
    least-loaded, falling back to least-loaded overall, deterministically
    in arrival order.  With homogeneous hints every pack cost is 0 and
    routing degrades exactly to the original least-loaded rule.  Requests
    never migrate; retirement, eviction and block recycling read and write
    nothing outside their shard — which is exactly what lets each shard
    live on its own host with no coherence traffic beyond routing.

    Exactness (property-tested in tests/test_sharding.py): a stream's
    tokens depend only on its own seed and its shard's model calls, and
    padded pool calls are bit-identical regardless of co-resident rows —
    so for the same arrival order the sharded engine emits exactly the
    unsharded engine's tokens, for both strategies, both verifiers,
    synchronous and pipelined stepping.  Scheduling-dependent *truncation*
    (eviction) also coincides whenever the eviction bound is per-stream
    (capacity eviction with homogeneous actions); block-pressure eviction
    is shard-local by design and compared against per-shard expectations
    instead (docs/serving.md "Sharded streams").

    ``n_slots`` that does not divide ``data_shards`` is padded UP
    (launch/sharding.pad_slots) — idle rows cost padding lanes, a
    replicated shard would cost HBM and the shard-local free-list
    invariant.  A given total ``pool_blocks`` is split evenly (ceil) so
    every shard's arena gates its own admissions.
    """

    def __init__(self, target_cfg, target_params, draft_cfg, draft_params,
                 ecfg: EngineConfig, sampling: SamplingParams | None = None,
                 selector=None, n_slots: int = 4, data_shards: int = 2,
                 paged: bool = True, block_size: int = 64,
                 pool_blocks: int | None = None, pipeline: bool = False,
                 meshes=None, ragged=True):
        assert data_shards >= 1, data_shards
        self.data_shards = data_shards
        self.n_slots = pad_slots(n_slots, data_shards)
        per_slots = self.n_slots // data_shards
        per_blocks = None
        if paged and pool_blocks is not None:
            per_blocks = -(-pool_blocks // data_shards)
        if meshes is None:
            meshes = shard_meshes(data_shards)
        assert len(meshes) == data_shards, (len(meshes), data_shards)
        self.shards = [
            BatchedSpeculativeEngine(
                target_cfg, target_params, draft_cfg, draft_params, ecfg,
                sampling, selector=selector, n_slots=per_slots, paged=paged,
                block_size=block_size, pool_blocks=per_blocks,
                pipeline=pipeline, mesh=meshes[i], shard_id=i, ragged=ragged)
            for i in range(data_shards)
        ]
        s0 = self.shards[0]
        self.paged, self.strategy, self.pipeline = s0.paged, s0.strategy, pipeline
        self.ecfg = ecfg
        if s0.paged:
            self.block_size = s0.block_size
            self.pool_blocks = s0.pool_blocks * data_shards
        self.finished: dict[int, dict] = {}
        self._next_rid = 0
        self._local: dict[int, tuple[int, int]] = {}   # global rid -> (shard, local rid)
        self._global: dict[tuple[int, int], int] = {}  # (shard, local rid) -> global rid
        # bin-packing state: global rid -> (shard, expected speculation
        # bucket Tpad) for every live routed request, pruned lazily against
        # _local at submit().  Scheduler-only — shapes no shard-local
        # decision and never migrates a stream (see _route)
        self._resident: dict[int, tuple[int, int]] = {}
        # grouped cross-shard commit (see _commit_shards): legal only when
        # every shard's pool lives on the same device set, which is exactly
        # the host-local smoke topology shard_meshes produces by cycling a
        # short device list
        devs = [tuple(sh.mesh.devices.flat) for sh in self.shards]
        self._colocated = all(d == devs[0] for d in devs)
        self._jit_cache: dict = {}
        # engine-level commit counters: a grouped commit is ONE dispatch
        # that belongs to no single shard (the counters property merges
        # these into the summed per-shard view)
        self._counters = {"commit_calls": 0, "commit_ms": 0.0}

    # --------------------------------------------------------- scheduling ---

    @staticmethod
    def _action_tpad(action) -> int:
        """Speculation bucket (Tpad) a lone stream with this (K, L1, L2)
        action would occupy — the bin-packing coordinate.  Uses the engines'
        own shape-bucketing rule so 'similar action' means exactly 'same
        compiled tree-pass bucket'."""
        return BatchedSpeculativeEngine._bucket_actions({0: tuple(action)})[3]

    def _pack_cost(self, si: int, tpad: int) -> int:
        """Padding lanes (per iteration) that co-residency with shard
        ``si``'s routed streams would add: a shard steps at the max of its
        residents' buckets, so joining costs this stream (new_max - tpad)
        lanes and costs each resident any growth of that max.  0 for an
        empty shard and whenever every bucket matches — with homogeneous
        actions all costs are 0 and routing degrades EXACTLY to the
        original least-loaded rule."""
        res = [t for s, t in self._resident.values() if s == si]
        if not res:
            return 0
        cur = max(res)
        new = max(cur, tpad)
        return (new - tpad) + len(res) * (new - cur)

    def _route(self, prompt_len: int, tpad: int) -> int:
        """Shard that can admit now with the cheapest bin-packing cost for
        this request's expected speculation bucket; least-loaded breaks
        cost ties and least-loaded overall applies when none can admit (the
        request queues there).  Load = resident + queued, ties to the
        lowest shard id — a pure function of arrival order and hints, so
        the schedule (and therefore any eviction truncation) is
        deterministic and arrival-order-stable.  Routing is the ONLY
        cross-shard state: placement never migrates a running stream."""
        admitting = [i for i, sh in enumerate(self.shards)
                     if sh.can_admit(prompt_len)]
        pool = admitting or range(self.data_shards)
        return min(pool, key=lambda i: (self._pack_cost(i, tpad),
                                        len(self.shards[i].streams)
                                        + len(self.shards[i].queue), i))

    def shard_of(self, rid: int) -> int:
        """Which shard a live (unfinished) request was routed to."""
        return self._local[rid][0]

    def submit(self, prompt: list[int], max_new: int = 64, seed: int | None = None,
               action_hint=None) -> int:
        """Route to a shard (bin-packing on ``action_hint``, the request's
        expected (K, L1, L2) selector action — default: the engine-config
        action, under which routing is plain least-loaded) and queue it
        there.  Hints only steer placement; the resident selector still
        decides every stream's real per-iteration action."""
        self._resident = {r: v for r, v in self._resident.items()
                          if r in self._local}
        hint = tuple(action_hint) if action_hint is not None else (
            self.ecfg.K, self.ecfg.L1, self.ecfg.L2)
        tpad = self._action_tpad(hint)
        si = self._route(len(prompt), tpad)
        lrid = self.shards[si].submit(prompt, max_new=max_new, seed=seed)
        rid = self._next_rid
        self._next_rid += 1
        self._local[rid] = (si, lrid)
        self._global[(si, lrid)] = rid
        self._resident[rid] = (si, tpad)
        return rid

    def _collect(self, si: int, events: list[dict]) -> list[dict]:
        """Rewrite a shard's events/finished payloads to global rids."""
        out = []
        for ev in events:
            ev = dict(ev)
            ev["rid"] = self._global[(si, ev["rid"])]
            out.append(ev)
        sh = self.shards[si]
        while sh.finished:
            lrid, info = sh.finished.popitem()
            rid = self._global.pop((si, lrid))
            del self._local[rid]
            self.finished[rid] = info
        return out

    # --------------------------------------------------------------- steps ---

    def _jit(self, name, fn, donate_argnums=None):
        """Engine-level jit cache for the grouped cross-shard commit (the
        shards keep their own caches for everything shard-local)."""
        if name not in self._jit_cache:
            kw = {} if donate_argnums is None else {"donate_argnums": donate_argnums}
            self._jit_cache[name] = jax.jit(fn, **kw)
        return self._jit_cache[name]

    def jit_compile_count(self) -> int:
        """Compile budget of the whole sharded deployment: every shard's jit
        cache plus the engine-level grouped-commit cache."""
        return (sum(sh.jit_compile_count() for sh in self.shards)
                + sum(_compiled_signatures(fn) for fn in self._jit_cache.values()))

    def _finish_order(self, sis: list[int]) -> list[int]:
        """The order shards' in-flight steps are VERIFIED in.  Shards are
        independent and verification touches only shard-local state, so any
        permutation yields identical tokens — the default is shard order;
        the race harness (tests/test_race.py) overrides this to shuffle
        host-side completion order under a seed."""
        return list(sis)

    def step(self) -> list[dict]:
        """Advance every shard one speculative block, CONCURRENTLY across
        shards: every shard's ``begin_step`` dispatches before any shard's
        verification blocks, so one shard's host-side verify loop hides
        behind the other shards' in-flight device work (on a multi-device
        host the shard passes themselves also overlap).  Then all verified
        shards commit in ONE grouped dispatch (``_commit_shards``) and
        retire in shard order — the retire phase runs each shard's
        pipeline-ahead dispatch when pipelining, so the next iteration's
        device work is already in flight when this call returns."""
        events = []
        # phase 1 — begin: surface drained events, then dispatch every
        # shard's step (consuming a begun-ahead step where one is pending)
        # before any verification blocks on a device future
        pendings: list = []
        for si, sh in enumerate(self.shards):
            drained, sh._drained_events = sh._drained_events, []
            events.extend(self._collect(si, drained))
            pending, sh._pending_next = sh._pending_next, None
            if pending is None:
                pending = sh.begin_step()
            pendings.append(pending)
        live = [si for si, p in enumerate(pendings) if p is not None]
        # phase 2 — verify: per-stream host walks, one shard at a time,
        # while the remaining shards' dispatched passes keep the device busy
        verified = {si: self.shards[si].verify_step(pendings[si])
                    for si in self._finish_order(live)}
        # phase 3 — commit: one grouped dispatch across shards
        self._commit_shards(verified)
        # phase 4 — retire (shard order, so event order is deterministic
        # regardless of the verify permutation)
        for si in sorted(verified):
            events.extend(self._collect(
                si, self.shards[si].retire_step(verified[si])))
        # a shard whose boundary came up empty can still have retired a
        # stream there (capacity eviction) — surface its finished payloads
        for si in range(self.data_shards):
            if si not in verified:
                events.extend(self._collect(si, []))
        return events

    def _commit_shards(self, verified: dict[int, VerifiedStep]) -> None:
        """Commit every verified shard's accepted paths.  Tree-strategy
        shards that share a device batch their staged index tables into ONE
        jitted, pool-donating dispatch (serve_step.make_group_commit_step)
        — restoring single-shard ``commit_calls``/``commit_ms`` — and fall
        back to per-shard commits when alone, un-colocated, or on the
        replay strategy (whose commit is a host-interleaved re-advance)."""
        group = sorted(verified) if self.strategy == "tree" and self._colocated \
            else []
        if len(group) <= 1:
            for si in sorted(verified):
                self.shards[si].commit_step(verified[si])
            return
        sigs, tables, caches = [], [], []
        for si in group:
            sh, v = self.shards[si], verified[si]
            npath, plen, Cb, act, P = sh._commit_tables(v.pending.active,
                                                        v.node_paths)
            sigs.append((v.pending.pads[3], P))
            tables.append((npath, plen, Cb, act))
            caches.append(sh.tpool.cache)
        key = "gcommit_" + "_".join(f"s{si}T{t}P{p}"
                                    for si, (t, p) in zip(group, sigs))
        fn = self._jit(key, make_group_commit_step(self.shards[0].tc,
                                                   [t for t, _ in sigs]),
                       donate_argnums=0)
        t0 = time.perf_counter()
        out = fn(tuple(caches),
                 tuple(jnp.asarray(t[0]) for t in tables),
                 tuple(jnp.asarray(t[1]) for t in tables),
                 tuple(jnp.asarray(t[2]) for t in tables),
                 tuple(jnp.asarray(t[3]) for t in tables))
        for si, cache in zip(group, out):
            self.shards[si].tpool.cache = cache
        if self.profile_commits:
            jax.block_until_ready(out)
        self._counters["commit_calls"] += 1
        self._counters["commit_ms"] += (time.perf_counter() - t0) * 1e3

    def drain_pipeline(self) -> list[dict]:
        """Drain every shard's begun-ahead step (see
        BatchedSpeculativeEngine.drain_pipeline)."""
        events = []
        for si, sh in enumerate(self.shards):
            events.extend(self._collect(si, sh.drain_pipeline()))
        return events

    def abort_pipeline(self) -> int:
        """Rewind EVERY shard's begun-ahead step (each shard restores its
        own rng snapshots and pool state — ``abort_step``).  Returns how
        many shards rewound a step; with several shards begun ahead all of
        them must land, or the next boundary would replay some shards'
        randomness against others' already-consumed state."""
        return sum(sh.abort_pipeline() for sh in self.shards)

    def run(self) -> dict[int, dict]:
        """Drain all shards; returns ``{rid: {"tokens", "reason"}}`` for the
        requests completed by this call (global rids)."""
        done: dict[int, dict] = {}

        def drain():
            while self.finished:
                rid, info = self.finished.popitem()
                done[rid] = info

        drain()
        while any(sh.queue or sh.streams for sh in self.shards):
            before = len(done)
            self.step()
            drain()
            if not any(sh.queue or sh.streams for sh in self.shards):
                break
            assert any(sh.streams for sh in self.shards) or len(done) > before, \
                "sharded scheduler stalled"
        return done

    def generate_batch(self, prompts, max_new: int = 32, seeds=None) -> list[list[int]]:
        """Convenience: submit all prompts, drain, return outputs in order."""
        rids = [
            self.submit(list(p), max_new, None if seeds is None else seeds[i])
            for i, p in enumerate(prompts)
        ]
        out = self.run()
        return [out[r]["tokens"] for r in rids]

    # ------------------------------------------------------------ counters ---

    @property
    def counters(self) -> dict:
        """Work/overlap counters summed across shards, plus the engine-level
        grouped-commit counters (a grouped commit is one dispatch belonging
        to no single shard).  Read-only view; use ``reset_counters`` or the
        per-shard dicts to mutate."""
        out: dict = {}
        for sh in self.shards:
            for key, val in sh.counters.items():
                out[key] = out.get(key, type(val)()) + val
        for key, val in self._counters.items():
            out[key] = out.get(key, type(val)()) + val
        return out

    def reset_counters(self, keys) -> None:
        for sh in self.shards:
            for key in keys:
                sh.counters[key] = type(sh.counters[key])()
        for key in keys:
            if key in self._counters:
                self._counters[key] = type(self._counters[key])()

    @property
    def profile_commits(self) -> bool:
        return self.shards[0].profile_commits

    @profile_commits.setter
    def profile_commits(self, value: bool) -> None:
        for sh in self.shards:
            sh.profile_commits = value

    @property
    def queue(self) -> list:
        """All shards' queued requests (routing already fixed their shard)."""
        return [req for sh in self.shards for req in sh.queue]

    @property
    def streams(self) -> dict:
        """(shard, slot) -> stream state across shards, for observability."""
        return {(si, s): st for si, sh in enumerate(self.shards)
                for s, st in sh.streams.items()}

    def pool_occupancy(self) -> dict:
        """Aggregate arena occupancy in the unsharded schema, plus the
        per-shard breakdown benchmarks surface (the whole point of the
        shard counters: a balanced scheduler shows near-equal per-shard
        peaks)."""
        per = [sh.pool_occupancy() for sh in self.shards]
        out: dict = {}
        for name in ("target", "draft"):
            shards = [p[name] for p in per if name in p]
            if not shards:
                continue
            used = sum(s["blocks_used"] for s in shards)
            out[name] = {
                "blocks_total": sum(s["blocks_total"] for s in shards),
                "blocks_used": used,
                "blocks_free": sum(s["blocks_free"] for s in shards),
                "block_size": shards[0]["block_size"],
                "fragmentation": (sum(s["fragmentation"] * s["blocks_used"]
                                      for s in shards) / used) if used else 0.0,
            }
        if out:
            out["per_shard"] = per
        return out
