"""Activation sharding constraints (ZeRO-3/FSDP semantics).

Sharding weights' d_in on the data axis is only half of FSDP: without
activation constraints GSPMD may satisfy the contraction by *replicating the
activations over batch* (observed: 16x attention flops at train_4k, §Perf
cycle 1).  Pinning every block input to batch-sharded layout forces the
compiler to all-gather weights instead — the ZeRO-3 schedule.

The launch layer installs the constraint (mesh + batch axes); model code
calls ``pin`` on block inputs.  No-op when nothing is installed (single-host
training, engine, tests).
"""
from __future__ import annotations

from contextlib import contextmanager

import jax

_STATE: dict = {"mesh": None, "axes": None}


def install(mesh, axes) -> None:
    _STATE["mesh"] = mesh
    _STATE["axes"] = axes


def clear() -> None:
    _STATE["mesh"] = None
    _STATE["axes"] = None


@contextmanager
def activation_sharding(mesh, axes):
    install(mesh, axes)
    try:
        yield
    finally:
        clear()


def pin(x: jax.Array) -> jax.Array:
    """Constrain a (B, ...) activation to batch sharding (if installed and
    the batch divides)."""
    mesh, axes = _STATE["mesh"], _STATE["axes"]
    if mesh is None or x.ndim < 2:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    import numpy as np

    total = int(np.prod([mesh.shape[a] for a in axes]))
    if x.shape[0] % total != 0:
        return x
    spec = P(axes if len(axes) > 1 else axes[0], *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def pin_moe_buffer(buf: jax.Array) -> jax.Array:
    """Constrain an (E, C, D) expert-capacity buffer to 2D sharding:
    experts -> model (expert parallel), capacity -> data.  Without this the
    scatter-built buffer replicates its capacity dim on every data shard
    (§Perf cycle 5)."""
    mesh, axes = _STATE["mesh"], _STATE["axes"]
    if mesh is None or buf.ndim != 3:
        return buf
    from jax.sharding import NamedSharding, PartitionSpec as P

    E, C, D = buf.shape
    m_ok = "model" in mesh.axis_names and E % mesh.shape["model"] == 0
    import numpy as np

    total = int(np.prod([mesh.shape[a] for a in axes]))
    c_ok = C % total == 0
    spec = P(
        "model" if m_ok else None,
        (axes if len(axes) > 1 else axes[0]) if c_ok else None,
        None,
    )
    return jax.lax.with_sharding_constraint(buf, NamedSharding(mesh, spec))
