"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-gated linear recurrent unit:

    r_t = sigmoid(W_a x_t + b_a)                    (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)                    (input gate)
    a_t = exp(c * softplus(Lambda) * (-r_t))        (log-space decay, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

computed with an associative scan over (a, b) pairs (the recurrence is linear
given the gates), preceded by a temporal causal conv (kernel 4) and wrapped in
the Griffin recurrent-block projections.  Decode carries (h, conv-tail) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import init_dense
from repro.models.ssm import _causal_conv

_C = 8.0


def init_rglru(cfg, key):
    d, dl = cfg.d_model, cfg.lru_d
    nb = cfg.lru_blocks
    assert dl % nb == 0, (dl, nb)
    bd = dl // nb
    ks = jax.random.split(key, 6)
    dt = cfg.jdtype
    blk = lambda k: (jax.random.normal(k, (nb, bd, bd), jnp.float32) / np.sqrt(bd))
    return {
        "w_x": init_dense(ks[0], d, dl, dt),  # input branch
        "w_y": init_dense(ks[1], d, dl, dt),  # gate branch (GeGLU-style)
        "conv_w": (jax.random.normal(ks[2], (4, dl), jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((dl,), dt),
        # block-diagonal gates (Griffin): communication-free under TP
        "w_a": blk(ks[3]),
        "b_a": jnp.zeros((dl,), jnp.float32),
        "w_i": blk(ks[4]),
        "b_i": jnp.zeros((dl,), jnp.float32),
        "lam": jnp.asarray(np.linspace(-4.3, -11.5, dl), jnp.float32),  # a in (.9, .999)
        "w_out": init_dense(ks[5], dl, d, dt),
    }


def _block_gate(x, w):
    """x: (B, S, dl); w: (nb, bd, bd) block-diagonal -> (B, S, dl)."""
    B, S, dl = x.shape
    nb, bd, _ = w.shape
    xr = x.reshape(B, S, nb, bd)
    return jnp.einsum("bsnd,nde->bsne", xr, w).reshape(B, S, dl)


def _lru_scan(log_a: jax.Array, b: jax.Array, h0: jax.Array | None):
    """h_t = exp(log_a_t) h_{t-1} + b_t  via associative scan over time axis 1.
    log_a, b: (B, S, D)."""

    def combine(x, y):
        la1, b1 = x
        la2, b2 = y
        return la1 + la2, b1 * jnp.exp(la2) + b2

    la_c, b_c = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    if h0 is not None:
        b_c = b_c + h0[:, None, :] * jnp.exp(la_c)
    return b_c


def rglru_apply(p, cfg, u: jax.Array, cache: dict | None):
    """u: (B, S, d_model) -> (out, new_cache)."""
    x = u @ p["w_x"]
    gate = jax.nn.gelu(u @ p["w_y"])
    conv_tail = cache.get("conv") if cache else None
    x, new_tail = _causal_conv(x, p["conv_w"], p["conv_b"], conv_tail)

    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(_block_gate(xf, p["w_a"]) + p["b_a"])
    i = jax.nn.sigmoid(_block_gate(xf, p["w_i"]) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r  # (B, S, D), negative
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-9)) * (i * xf)

    h0 = cache.get("state") if cache else None
    if x.shape[1] == 1 and h0 is not None:
        h = h0 * jnp.exp(log_a[:, 0]) + b[:, 0]
        y = h[:, None]
        new_state = h
    else:
        y = _lru_scan(log_a, b, h0)
        new_state = y[:, -1]
    out = (y.astype(u.dtype) * gate) @ p["w_out"]
    return out, {"state": new_state, "conv": new_tail}
