"""KV / recurrent-state cache structures.

Attention caches are ring buffers of size ``Smax`` (= window for
sliding-window archs): slot = position % Smax, with absolute positions stored
so masks can express both causality and the sliding window uniformly.  All
requests in a batch advance in lockstep (the engine pads), so ``len`` and
``pos`` are shared across the batch.

Layout (leading layer axis L, scanned):
    attn:  k, v: (L, B, Smax, Hkv, hd);  pos: (Smax,) int32;  len: () int32
    ssm:   state: (L, B, H, P, N); conv: (L, B, K-1, C);      len: () int32
    rglru: state: (L, B, D); conv: (L, B, 3, D);              len: () int32
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_attn_cache(cfg, n_layers: int, batch: int, smax: int, dtype):
    hd = cfg.hd
    return {
        "k": jnp.zeros((n_layers, batch, smax, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((n_layers, batch, smax, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((smax,), -1, jnp.int32),
        "len": jnp.zeros((), jnp.int32),
    }


def cache_slots(length: jax.Array, T: int, smax: int) -> jax.Array:
    return (length + jnp.arange(T, dtype=jnp.int32)) % smax


def append_layer_kv(k_cache, v_cache, k_new, v_new, slots):
    """k_cache: (B, Smax, Hkv, hd); k_new: (B, T, Hkv, hd); slots: (T,)."""
    return k_cache.at[:, slots].set(k_new.astype(k_cache.dtype)), v_cache.at[:, slots].set(
        v_new.astype(v_cache.dtype)
    )


def attn_mask_from_pos(pos: jax.Array, q_positions: jax.Array, window: int = 0) -> jax.Array:
    """(T, Smax) mask: slot valid iff 0 <= pos[s] <= q_pos[t] (and within the
    window when sliding).  q_positions: (T,) absolute positions of queries."""
    s = pos[None, :]
    t = q_positions[:, None]
    m = (s >= 0) & (s <= t)
    if window:
        m = m & (s > t - window)
    return m[None, None]  # (1, 1, T, Smax)


def tree_mask_from_pos(
    pos: jax.Array, q_positions: jax.Array, anc: jax.Array, self_slots: jax.Array, window: int = 0
) -> jax.Array:
    """Tree-pass mask over cache slots that now *contain* the tree tokens.

    The T tree tokens were appended into ``self_slots``; a tree token may
    attend to (a) any older cache slot per the causal/window rule against the
    *branch-context* boundary, and (b) its tree ancestors (anc, (T, T),
    including self).
    """
    base = attn_mask_from_pos(pos, q_positions, window)[0, 0]  # (T, Smax)
    # cut out the tree's own slots from the causal rule, then re-add ancestors
    is_self = jnp.zeros(pos.shape, bool).at[self_slots].set(True)  # (Smax,)
    base = base & ~is_self[None, :]
    if anc.ndim == 3:  # batched ancestor masks (B, T, T)
        tree_part = (
            jnp.zeros((anc.shape[0],) + base.shape, bool)
            .at[:, :, self_slots]
            .set(anc.astype(bool))
        )
        return (base[None] | tree_part)[:, None]  # (B, 1, T, Smax)
    tree_part = jnp.zeros(base.shape, bool).at[:, self_slots].set(anc.astype(bool))
    return (base | tree_part)[None, None]  # (1, 1, T, Smax)
