"""KV / recurrent-state cache structures.

Attention caches are ring buffers of size ``Smax`` (= window for
sliding-window archs): slot = position % Smax, with absolute positions stored
so masks can express both causality and the sliding window uniformly.

Two layouts:

  * lockstep (``per_stream=False``): all requests advance together, so
    ``len`` and ``pos`` are shared across the batch (the training / dryrun
    shapes, and the single-stream engine).
  * per-stream (``per_stream=True``): ``len`` is (B,) and ``pos`` is
    (B, Smax) so every batch row holds an independent stream at its own
    sequence position.  This is the substrate of the continuous-batching
    engine: rows join/leave a fixed-capacity pool without recompiles.

Layout (leading layer axis L, scanned):
    attn:  k, v: (L, B, Smax, Hkv, hd);  pos: (Smax,) or (B, Smax) int32;
           len: () or (B,) int32
    ssm:   state: (L, B, H, P, N); conv: (L, B, K-1, C);  len: () or (B,)
    rglru: state: (L, B, D); conv: (L, B, 3, D);          len: () or (B,)

Paged layout (``init_paged_attn_cache``): KV storage is a global arena of
fixed-size blocks shared by every stream,

    attn:  k, v: (L, NBLK, block, Hkv, hd) arena;
           block_tbl: (B, max_blocks) int32 physical block id, -1 unmapped;
           pos: (B, Smax) int32;  len: (B,) int32,   Smax = max_blocks*block

so a stream's *logical* ring of Smax slots is an indirection over arena
blocks: logical slot s lives at arena lane (block_tbl[b, s // block],
s % block).  Physical block 0 is reserved as the TRASH block: unmapped
table entries clamp to it, so writes through an unmapped (or idle-row)
table land in lanes no mask ever admits — pos stays -1 for unmapped
logical slots, and masked lanes contribute exact zeros to softmax sums
regardless of content.  This makes the paged pool *token-identical* to the
per-stream ring with the same Smax while HBM holds only the blocks streams
actually map (long and short streams co-resident; eviction = block
recycling).  See docs/serving.md for the lifecycle and docs/kernels.md
for the kernel-facing contracts.

Ring-compaction commit contract (serving/serve_step.make_pool_commit_step;
documented in full in docs/kernels.md):
a tree pass appends a block of Tpad speculative tokens at slots
(C + t) % Smax for t = 0..Tpad-1, where C is the row's committed length
before the block (so the pending root token sits at slot C % Smax).
Committing an accepted node path [n_1 < n_2 < ... < n_tau] then

  * moves KV lanes  (C + n_j) % Smax  ->  (C + j) % Smax  for j = 1..tau
    (dst slots are the contiguous run C+1 .. C+tau);
  * invalidates every block slot: pos[(C + t) % Smax] = -1 for the whole
    padded block, for every layer-shared pos table of the row;
  * rewrites pos over the surviving run: pos[(C + j) % Smax] = C + j for
    j = 0..tau (the root at C stays committed);
  * advances the row's len to C + 1 + tau.

Accepted node indices are strictly increasing with n_j >= j + 1 (deeper
tree nodes are always appended later), so a source slot is never an
EARLIER entry's destination (n_j = i + 1 needs i >= j) and destinations
are pairwise distinct: every entry reads its pre-commit value, making the
sequential in-place copy (kernels/commit_kv.py) exactly gather-then-
scatter.  Ragged paths pad with identity copies of the root slot, which
no real entry writes.  Under paging the same contract holds after
translating logical slots through the block table: rows own disjoint
physical blocks, so the concatenated per-row index lists stay hazard-free
(idle/padding entries translate into the trash block, still src == dst).
"""
from __future__ import annotations

import heapq

import jax
import jax.numpy as jnp
import numpy as np


def init_attn_cache(cfg, n_layers: int, batch: int, smax: int, dtype, per_stream: bool = False):
    hd = cfg.hd
    return {
        "k": jnp.zeros((n_layers, batch, smax, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((n_layers, batch, smax, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((batch, smax) if per_stream else (smax,), -1, jnp.int32),
        "len": jnp.zeros((batch,) if per_stream else (), jnp.int32),
    }


TRASH_BLOCK = 0  # physical arena block 0: the write sink for unmapped table entries


def init_paged_attn_cache(cfg, n_layers: int, batch: int, n_blocks: int, block: int,
                          smax: int, dtype):
    """Paged attention cache: a block arena + per-stream block tables.

    ``n_blocks`` counts *usable* blocks; one extra trash block (physical id
    0) is always allocated, so the arena holds n_blocks + 1 blocks of
    ``block`` slots each.  ``smax`` is the per-stream logical capacity and
    must be a multiple of ``block`` (max_blocks = smax // block table
    columns)."""
    assert smax % block == 0, (smax, block)
    hd = cfg.hd
    return {
        "k": jnp.zeros((n_layers, n_blocks + 1, block, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((n_layers, n_blocks + 1, block, cfg.n_kv_heads, hd), dtype),
        "block_tbl": jnp.full((batch, smax // block), -1, jnp.int32),
        "pos": jnp.full((batch, smax), -1, jnp.int32),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def is_paged(cache: dict) -> bool:
    """True when the cache's attention component is block-table indirect."""
    return "attn" in cache and "block_tbl" in cache["attn"]


def paged_phys_slots(tbl: jax.Array, slots: jax.Array, block: int) -> jax.Array:
    """Translate logical ring slots to flat arena lane indices.

    tbl (B, max_blocks) int32; slots (B, T) logical.  Unmapped entries clamp
    to the trash block, so callers may write through them unconditionally."""
    blk = jnp.take_along_axis(tbl, slots // block, axis=1)
    return jnp.clip(blk, 0) * block + slots % block


def paged_append_layer_kv(k_arena, v_arena, k_new, v_new, slots, tbl):
    """Per-layer paged KV write.  k_arena: (NBLK, block, Hkv, hd);
    k_new: (B, T, Hkv, hd); slots: (B, T) logical; tbl: (B, max_blocks)."""
    nb, block = k_arena.shape[0], k_arena.shape[1]
    phys = paged_phys_slots(tbl, slots, block).reshape(-1)
    kf = k_arena.reshape((nb * block,) + k_arena.shape[2:])
    vf = v_arena.reshape((nb * block,) + v_arena.shape[2:])
    kf = kf.at[phys].set(k_new.reshape((-1,) + k_new.shape[2:]).astype(kf.dtype))
    vf = vf.at[phys].set(v_new.reshape((-1,) + v_new.shape[2:]).astype(vf.dtype))
    return kf.reshape(k_arena.shape), vf.reshape(v_arena.shape)


def paged_layer_view(k_arena, v_arena, tbl):
    """Materialize the logical (B, Smax, Hkv, hd) view of one layer's arena.

    Unmapped blocks read the trash block — garbage lanes, but every one of
    them carries pos = -1 so no attention mask admits them (their softmax
    contribution is exactly zero, preserving bit-identity with the dense
    per-stream ring).  The Pallas kernels (kernels/tree_attention.py,
    kernels/decode_attention.py) stream blocks through the table instead of
    materializing this view; kernels/ref.py `paged_gather_kv_ref` is the
    shared oracle."""
    phys = jnp.clip(tbl, 0)  # (B, max_blocks)
    B, nb = phys.shape
    block = k_arena.shape[1]
    kd = k_arena[phys].reshape((B, nb * block) + k_arena.shape[2:])
    vd = v_arena[phys].reshape((B, nb * block) + v_arena.shape[2:])
    return kd, vd


def cache_slots(length: jax.Array, T: int, smax: int) -> jax.Array:
    """(T,) slots for scalar length; (B, T) for per-stream (B,) lengths."""
    off = jnp.arange(T, dtype=jnp.int32)
    if getattr(length, "ndim", 0) == 1:
        return (length[:, None] + off[None, :]) % smax
    return (length + off) % smax


def append_layer_kv(k_cache, v_cache, k_new, v_new, slots):
    """k_cache: (B, Smax, Hkv, hd); k_new: (B, T, Hkv, hd);
    slots: (T,) shared or (B, T) per stream."""
    if slots.ndim == 2:
        b = jnp.arange(k_cache.shape[0])[:, None]
        return (
            k_cache.at[b, slots].set(k_new.astype(k_cache.dtype)),
            v_cache.at[b, slots].set(v_new.astype(v_cache.dtype)),
        )
    return k_cache.at[:, slots].set(k_new.astype(k_cache.dtype)), v_cache.at[:, slots].set(
        v_new.astype(v_cache.dtype)
    )


def attn_mask_from_pos(pos: jax.Array, q_positions: jax.Array, window: int = 0) -> jax.Array:
    """Mask: slot valid iff 0 <= pos[s] <= q_pos[t] (and within the window
    when sliding).  pos: (Smax,) or (B, Smax); q_positions: (T,) or (B, T)
    absolute positions of queries.  Returns (1, 1, T, Smax) or
    (B, 1, T, Smax)."""
    s = pos[..., None, :]
    t = q_positions[..., :, None]
    m = (s >= 0) & (s <= t)
    if window:
        m = m & (s > t - window)
    return m[:, None] if m.ndim == 3 else m[None, None]


def tree_mask_from_pos(
    pos: jax.Array, q_positions: jax.Array, anc: jax.Array, self_slots: jax.Array, window: int = 0
) -> jax.Array:
    """Tree-pass mask over cache slots that now *contain* the tree tokens.

    The T tree tokens were appended into ``self_slots``; a tree token may
    attend to (a) any older cache slot per the causal/window rule against the
    *branch-context* boundary, and (b) its tree ancestors (anc, (T, T) or
    per-stream (B, T, T), including self).
    """
    if pos.ndim == 2:  # per-stream tables: pos (B, Smax), self_slots (B, T)
        B, T = self_slots.shape
        base = attn_mask_from_pos(pos, q_positions, window)[:, 0]  # (B, T, Smax)
        bidx = jnp.arange(B)[:, None]
        is_self = jnp.zeros(pos.shape, bool).at[bidx, self_slots].set(True)  # (B, Smax)
        base = base & ~is_self[:, None, :]
        anc_b = anc if anc.ndim == 3 else jnp.broadcast_to(anc[None], (B, T, T))
        tree_part = (
            jnp.zeros(base.shape, bool)
            .at[bidx[:, :, None], jnp.arange(T)[None, :, None], self_slots[:, None, :]]
            .set(anc_b.astype(bool))
        )
        return (base | tree_part)[:, None]  # (B, 1, T, Smax)
    base = attn_mask_from_pos(pos, q_positions, window)[0, 0]  # (T, Smax)
    # cut out the tree's own slots from the causal rule, then re-add ancestors
    is_self = jnp.zeros(pos.shape, bool).at[self_slots].set(True)  # (Smax,)
    base = base & ~is_self[None, :]
    if anc.ndim == 3:  # batched ancestor masks (B, T, T), shared slot table
        tree_part = (
            jnp.zeros((anc.shape[0],) + base.shape, bool)
            .at[:, :, self_slots]
            .set(anc.astype(bool))
        )
        return (base[None] | tree_part)[:, None]  # (B, 1, T, Smax)
    tree_part = jnp.zeros(base.shape, bool).at[:, self_slots].set(anc.astype(bool))
    return (base | tree_part)[None, None]  # (1, 1, T, Smax)


def ragged_tree_mask(
    pos: jax.Array, q_pos: jax.Array, owner: jax.Array, slots: jax.Array,
    parent: jax.Array, window: int = 0
) -> jax.Array:
    """Tree-pass mask for the RAGGED node-major layout (docs/serving.md
    "Ragged node-major tree batching").

    The active streams' trees live flattened in ONE (N,) node buffer:
    ``owner[i]`` is node i's pool row, ``slots[i]`` its ring slot in that
    row's pos table — ``Smax`` for padding lanes, the always-out-of-range
    sentinel that drop-mode scatters discard — ``parent[i]`` its FLAT parent
    index (-1 for roots and padding) and ``q_pos[i]`` its absolute position.
    ``pos`` is the (B, Smax) slot table *after* writing the tree tokens.

    Row i of the returned (N, Smax) mask admits, over node i's OWNER row:
    committed slots per the causal/window rule, plus the slots holding node
    i's flat-tree ancestors (self included) — exactly the admit set of
    ``tree_mask_from_pos``'s per-stream branch, indexed by node instead of
    (row, tree-column), so the ragged pass stays bit-identical to padded.
    """
    N = owner.shape[0]
    smax = pos.shape[-1]
    p = pos[owner]  # (N, Smax): each node masks over its owner row's slots
    base = (p >= 0) & (p <= q_pos[:, None])
    if window:
        base = base & (p > q_pos[:, None] - window)
    # cut this pass's own slots out of the causal rule (they already carry
    # tree positions), then re-admit each node's ancestor slots explicitly
    is_self = jnp.zeros(pos.shape, bool).at[owner, slots].set(True, mode="drop")
    base = base & ~is_self[owner]
    idx = jnp.arange(N, dtype=jnp.int32)
    anc0 = idx[None, :] == idx[:, None]  # ancestor-or-self: start from self

    def chase(_, carry):
        anc, cur = carry
        nxt = jnp.where(cur >= 0, parent[jnp.maximum(cur, 0)], -1)
        return anc | (idx[None, :] == nxt[:, None]), nxt

    anc, _ = jax.lax.fori_loop(0, N, chase, (anc0, idx))
    # scatter ancestor admits into slot columns; .max (bool OR), NOT .set:
    # two streams may reuse the same slot VALUE, and a foreign stream's
    # False must not wipe a True the owner stream already accumulated
    tree_part = (
        jnp.zeros((N, smax), bool)
        .at[idx[:, None], slots[None, :]]
        .max(anc, mode="drop")
    )
    return base | tree_part


# ---------------------------------------------------------- stream algebra ---
#
# Every cache array has at most one "stream" axis (the batch axis).  Its
# position depends on the array family; the walker below encodes that map
# once so fork/gather/scatter/merge work for every architecture.

_AXIS1 = ("state", "conv", "tail_state", "tail_conv", "cross_k", "cross_v")


def _walk(cache, other, fn):
    """Apply fn(dst, src, axis) over the cache pytree; axis None for arrays
    without a stream axis (lockstep pos/len)."""
    out = {}
    for key, val in cache.items():
        o = other[key] if other is not None else None
        if key == "attn":
            a = {}
            a["k"] = fn(val["k"], o["k"] if o else None, 1)
            a["v"] = fn(val["v"], o["v"] if o else None, 1)
            a["pos"] = fn(val["pos"], o["pos"] if o else None, 0 if val["pos"].ndim == 2 else None)
            a["len"] = fn(val["len"], o["len"] if o else None, 0 if val["len"].ndim == 1 else None)
            out[key] = a
        elif key in ("rec_state", "rec_conv"):
            out[key] = fn(val, o, 2)
        elif key in _AXIS1:
            out[key] = fn(val, o, 1)
        elif key == "len":
            out[key] = fn(val, o, 0 if val.ndim == 1 else None)
        else:
            out[key] = fn(val, o, None)
    return out


def _paged_gather_attn(attn: dict, rows: jax.Array) -> dict:
    """Materialize selected paged rows as a DENSE per-stream attn cache
    (k/v (L, R, Smax, Hkv, hd)) — the bridge that lets paged pools feed the
    row-sized dense sub-caches the engines' grouped forwards consume."""
    tblr = jnp.take(attn["block_tbl"], rows, axis=0)  # (R, nb)
    phys = jnp.clip(tblr, 0)
    block = attn["k"].shape[2]
    R, nb = phys.shape
    kd = attn["k"][:, phys].reshape((attn["k"].shape[0], R, nb * block) + attn["k"].shape[3:])
    vd = attn["v"][:, phys].reshape((attn["v"].shape[0], R, nb * block) + attn["v"].shape[3:])
    return {"k": kd, "v": vd, "pos": jnp.take(attn["pos"], rows, axis=0),
            "len": jnp.take(attn["len"], rows, axis=0)}


def _paged_scatter_attn(attn: dict, rows_attn: dict, slots: jax.Array) -> dict:
    """Write dense per-stream rows back through the block tables.  Content
    of logical blocks a row has not mapped lands in the trash block (the
    only lanes multiple rows may target — last writer wins, never read
    unmasked)."""
    tblr = jnp.take(attn["block_tbl"], slots, axis=0)  # (R, nb)
    phys = jnp.clip(tblr, 0)
    R, nb = phys.shape
    block = attn["k"].shape[2]
    k, v = attn["k"], attn["v"]
    kr = rows_attn["k"].reshape((k.shape[0], R, nb, block) + k.shape[3:]).astype(k.dtype)
    vr = rows_attn["v"].reshape((v.shape[0], R, nb, block) + v.shape[3:]).astype(v.dtype)
    return {
        "k": k.at[:, phys].set(kr),
        "v": v.at[:, phys].set(vr),
        "pos": attn["pos"].at[slots].set(rows_attn["pos"].astype(attn["pos"].dtype)),
        "len": attn["len"].at[slots].set(rows_attn["len"].astype(attn["len"].dtype)),
        "block_tbl": attn["block_tbl"],
    }


def _split_attn(cache: dict):
    return cache["attn"], {key: val for key, val in cache.items() if key != "attn"}


def fork_streams(cache: dict, K: int) -> dict:
    """Replicate every stream row K times along its stream axis (row b maps
    to rows b*K .. b*K+K-1).  Lockstep pos/len are shared, not replicated.

    A paged cache is first materialized to its dense per-stream view: forked
    branches write independent speculative KV, which a shared arena cannot
    hold (the forks would collide in the parent's blocks)."""
    if is_paged(cache):
        cache = gather_streams(cache, jnp.arange(cache["attn"]["len"].shape[0]))
    return _walk(cache, None, lambda a, _, ax: a if ax is None else jnp.repeat(a, K, axis=ax))


def gather_streams(cache: dict, rows) -> dict:
    """Select stream rows (a smaller cache over ``rows``, in order).

    Paged caches come back DENSE (per-stream rings over the rows' logical
    views): the result is a normal row-sized cache any forward can consume,
    and ``scatter_streams`` writes it back through the block tables."""
    rows = jnp.asarray(rows)
    if is_paged(cache):
        attn, rest = _split_attn(cache)
        out = _walk(rest, None, lambda a, _, ax: a if ax is None else jnp.take(a, rows, axis=ax))
        out["attn"] = _paged_gather_attn(attn, rows)
        return out
    return _walk(cache, None, lambda a, _, ax: a if ax is None else jnp.take(a, rows, axis=ax))


def scatter_streams(pool: dict, rows_cache: dict, slots) -> dict:
    """Write ``rows_cache`` stream rows into ``pool`` at ``slots`` (list of
    pool row indices, one per rows_cache row).  A paged pool takes dense
    per-stream rows (the ``gather_streams`` layout) and routes them through
    its block tables."""
    slots = jnp.asarray(slots)

    def put(dst, src, ax):
        if ax is None:
            return dst
        dst_m = jnp.moveaxis(dst, ax, 0)
        src_m = jnp.moveaxis(src, ax, 0).astype(dst_m.dtype)
        return jnp.moveaxis(dst_m.at[slots].set(src_m), 0, ax)

    if is_paged(pool):
        attn, rest = _split_attn(pool)
        rows_attn, rows_rest = _split_attn(rows_cache)
        out = _walk(rest, rows_rest, put)
        out["attn"] = _paged_scatter_attn(attn, rows_attn, slots)
        return out
    return _walk(pool, rows_cache, put)


def concat_streams(caches: list[dict]) -> dict:
    """Concatenate several per-stream caches along their stream axis.

    Used to fuse a step's row-sized sub-caches (one per length group) into a
    single rows-cache so the pool write-back is ONE scatter_streams call
    instead of one full-pool copy per group.  Arrays without a stream axis
    (lockstep pos/len) are taken from the first cache.
    """
    axes = _walk(caches[0], None, lambda a, _, ax: ax)

    def rec(vals, ax):
        if isinstance(vals[0], dict):
            return {key: rec([v[key] for v in vals], ax[key]) for key in vals[0]}
        if ax is None:
            return vals[0]
        return jnp.concatenate(vals, axis=ax)

    return rec(list(caches), axes)


def merge_streams(new: dict, old: dict, keep) -> dict:
    """Per-stream select: row b of the result is ``new``'s where keep[b],
    else ``old``'s.  The freeze primitive of padded lockstep stepping: rows
    whose stream has no real token this step keep their exact prior state.

    Paged attn arenas have no stream axis, so the freeze works at block
    granularity: a physical block takes ``new``'s content iff a keep=True
    row maps it (block tables are pairwise disjoint, so ownership is
    unambiguous; blocks owned by frozen rows, free blocks and the trash
    block keep ``old``'s lanes)."""
    keep = jnp.asarray(keep)

    def sel(n, o, ax):
        if ax is None:
            return n
        shape = [1] * n.ndim
        shape[ax] = keep.shape[0]
        return jnp.where(keep.reshape(shape), n, o)

    if is_paged(new):
        attn_n, rest_n = _split_attn(new)
        attn_o, rest_o = _split_attn(old)
        out = _walk(rest_n, rest_o, sel)
        tbl = attn_n["block_tbl"]
        nblk = attn_n["k"].shape[1]
        owned = (
            jnp.zeros((nblk,), jnp.int32)
            .at[jnp.clip(tbl, 0)]
            .add((keep[:, None] & (tbl >= 0)).astype(jnp.int32))
        ) > 0
        bsel = owned[None, :, None, None, None]
        out["attn"] = {
            "k": jnp.where(bsel, attn_n["k"], attn_o["k"]),
            "v": jnp.where(bsel, attn_n["v"], attn_o["v"]),
            "pos": jnp.where(keep[:, None], attn_n["pos"], attn_o["pos"]),
            "len": jnp.where(keep, attn_n["len"], attn_o["len"]),
            "block_tbl": jnp.where(keep[:, None], tbl, attn_o["block_tbl"]),
        }
        return out
    return _walk(new, old, sel)


class CachePool:
    """Fixed-capacity slot pool over a per-stream cache.

    Holds one batched cache of ``n_slots`` rows plus free-slot bookkeeping so
    streams can join (prefill a 1-row cache, scatter it in) and leave
    (release the slot) without any recompilation: every model call sees the
    same (n_slots, ...) shapes.

    Double-buffered rows (pipelined stepping, docs/serving.md): between
    ``begin_frame()`` and ``drop_frame()`` the pool holds a *back buffer* —
    the cache pytree as of the frame start — alongside the evolving front.
    Cache arrays are immutable, so the back buffer is a reference, not a
    copy; its only cost is that in-place donation of the front buffer must
    be suppressed while a frame is held (``frame_held``), since donating
    would hand the back buffer's storage to XLA.  ``rollback_frame()``
    restores the back buffer — the drain rule's rewind for a begun-but-
    abandoned pipelined step.

    Sharded pools (``sharding`` != None, a NamedSharding pytree from
    ``launch.sharding.pool_shardings``): the cache arrays are committed to
    the mesh data axis at construction — the stream axis physically lives
    where the sharding says.  Donated jit calls keep outputs on the same
    devices, so one ``device_put`` here pins the whole pool lifecycle; host
    index uploads that must land next to the pool (block-table pushes) are
    re-committed through the stored sharding leaf.
    """

    def __init__(self, cache: dict, n_slots: int, sharding=None):
        if sharding is not None:
            cache = jax.device_put(cache, sharding)
        self.sharding = sharding
        self.cache = cache
        self.n_slots = n_slots
        self._free = list(range(n_slots))
        self._back: dict | None = None

    # ---------------------------------------------- double-buffered rows ---

    @property
    def frame_held(self) -> bool:
        """True while a back buffer is alive: donating the front buffer is
        then forbidden (the back buffer aliases its pre-frame storage)."""
        return self._back is not None

    def begin_frame(self) -> None:
        """Hold the current cache as the back buffer.  One frame at a time:
        the pipelined engine begins a frame per in-flight step and either
        drops it (step retired) or rolls it back (step aborted)."""
        assert self._back is None, "frame already held"
        self._back = self.cache

    def drop_frame(self) -> None:
        """Release the back buffer (the in-flight step is being finished);
        the front buffer becomes donatable again."""
        self._back = None

    def rollback_frame(self) -> None:
        """Restore the back buffer as the live cache — every write since
        ``begin_frame`` (ingest, drafting) is discarded."""
        assert self._back is not None, "no frame to roll back"
        self.cache = self._back
        self._back = None

    def invalidate_from(self, starts: dict[int, int]) -> None:
        """Erase rows' speculative attention writes: for each {row: start},
        invalidate every pos lane holding a position >= start and rewind the
        row's len to start.  Slot arithmetic is logical, so this covers ring
        and paged layouts alike; the orphaned KV lanes keep their garbage but
        pos = -1 bars them from every mask (the trash-lane argument).  Used
        by the pipelined engine to abort a dispatched tree pass whose pool
        buffer was donated (the pre-pass buffer no longer exists)."""
        if not starts:
            return
        assert "attn" in self.cache, "invalidate_from targets attention caches"
        rows = np.fromiter(starts.keys(), np.int32)
        st = np.fromiter((starts[r] for r in rows), np.int32)
        attn = dict(self.cache["attn"])
        rows_j = jnp.asarray(rows)
        st_j = jnp.asarray(st)
        sub = attn["pos"][rows_j]
        attn["pos"] = attn["pos"].at[rows_j].set(jnp.where(sub >= st_j[:, None], -1, sub))
        attn["len"] = attn["len"].at[rows_j].set(st_j)
        cache = dict(self.cache)
        cache["attn"] = attn
        self.cache = cache

    # ------------------------------------------------------------- slots ---

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def acquire(self) -> int:
        if not self._free:
            raise RuntimeError("cache pool exhausted")
        return self._free.pop(0)

    def release(self, slot: int) -> None:
        assert slot not in self._free
        self._free.append(slot)
        self._free.sort()

    def admit(self, row_cache: dict, ctx_len: int = 0) -> int:
        """Scatter a freshly prefilled 1-row per-stream cache into a free slot."""
        slot = self.acquire()
        self.cache = scatter_streams(self.cache, row_cache, [slot])
        return slot


class PagedCachePool(CachePool):
    """Paged slot pool: the CachePool API over a block arena.

    On top of the row bookkeeping, streams own *blocks* from a shared free
    list (physical block 0 is the permanent trash block and is never handed
    out).  The host mirrors the block tables so allocation decisions never
    read device memory; every table change pushes one tiny (n_slots,
    max_blocks) int32 array.

    Lifecycle (see docs/serving.md):
      * ``admit(row, ctx_len)`` maps enough blocks for the prefilled
        context, then scatters the dense row through the table;
      * ``ensure(slot, upto)`` maps any unmapped logical blocks covering
        slots [0, upto) — called by the engine before each step's writes;
      * ``reclaim_tail(slot, keep_upto)`` unmaps blocks wholly past a
        stream's live frontier (their pos lanes are already -1 from the
        last commit's invalidation; reset defensively anyway) — the
        paged replacement for whole-stream cache-pressure eviction;
      * ``release(slot)`` returns every block to the free list.
    """

    def __init__(self, cache: dict, n_slots: int, sharding=None):
        super().__init__(cache, n_slots, sharding=sharding)
        assert is_paged(cache), "PagedCachePool needs a paged attn cache"
        attn = self.cache["attn"]
        self.block = int(attn["k"].shape[2])
        self.max_blocks = int(attn["block_tbl"].shape[1])
        self.total_blocks = int(attn["k"].shape[1]) - 1  # minus trash
        self._tbl = np.full((n_slots, self.max_blocks), -1, np.int32)
        # min-heap: allocation is deterministic lowest-id-first at O(log F)
        self._free_blocks = list(range(1, self.total_blocks + 1))
        self._pending_pos: dict[int, int] = {}  # deferred pos resets (reclaim_tails)

    # ------------------------------------------------------------ queries ---

    @property
    def free_blocks(self) -> int:
        return len(self._free_blocks)

    @property
    def used_blocks(self) -> int:
        return self.total_blocks - len(self._free_blocks)

    def blocks_for(self, upto: int) -> int:
        """Logical blocks covering slots [0, upto)."""
        return min(-(-max(upto, 0) // self.block), self.max_blocks)

    def missing_blocks(self, slot: int, upto: int) -> int:
        """How many of the blocks covering [0, upto) row ``slot`` has yet to map."""
        need = self.blocks_for(upto)
        return int(np.sum(self._tbl[slot, :need] < 0))

    def occupancy(self, frontiers=None) -> dict:
        """Arena counters for benchmarks: blocks used/free and internal
        fragmentation (mapped slots holding no live token, as a fraction of
        mapped slots).  ``frontiers`` maps row -> live slot count."""
        used = self.used_blocks
        frag = 0.0
        if frontiers and used:
            mapped = sum(int(np.sum(self._tbl[s] >= 0)) for s in frontiers) * self.block
            live = sum(min(f, self.max_blocks * self.block) for f in frontiers.values())
            frag = max(0.0, 1.0 - live / mapped) if mapped else 0.0
        return {"blocks_total": self.total_blocks, "blocks_used": used,
                "blocks_free": self.free_blocks, "block_size": self.block,
                "fragmentation": frag}

    # --------------------------------------------------------- allocation ---

    def _sync_tbl(self) -> None:
        tbl = jnp.asarray(self._tbl)
        if self.sharding is not None:
            # the table push must land on the pool's devices, or the next
            # jitted pool step sees inputs committed across devices
            tbl = jax.device_put(tbl, self.sharding["attn"]["block_tbl"])
        cache = dict(self.cache)
        cache["attn"] = dict(cache["attn"])
        cache["attn"]["block_tbl"] = tbl
        self.cache = cache

    def ensure(self, slot: int, upto: int, sync: bool = True) -> bool:
        """Map every unmapped logical block covering slots [0, upto).
        Returns False (mapping nothing further) once the free list runs dry —
        the caller reclaims tails or evicts, then retries.  ``sync=False``
        defers the device table push (use ``ensure_rows`` to batch)."""
        need = self.blocks_for(upto)
        idx = [i for i in range(need) if self._tbl[slot, i] < 0]
        if len(idx) > len(self._free_blocks):
            return False
        if idx:
            for i in idx:
                self._tbl[slot, i] = heapq.heappop(self._free_blocks)
            if sync:
                self._sync_tbl()
        return True

    def ensure_rows(self, frontiers: dict) -> bool:
        """Map every row's frontier ({slot: upto}) with ONE device table
        push — the per-step form (one H2D regardless of how many rows cross
        a block boundary).  All-or-nothing per row, like ``ensure``."""
        ok = True
        for slot, upto in frontiers.items():
            ok = self.ensure(slot, upto, sync=False) and ok
        self._sync_tbl()
        return ok

    def _reset_pos_tails(self, starts: dict) -> None:
        """Set pos[slot, start:] = -1 for every {slot: start} in one
        gather/where/scatter round instead of one dispatch per row."""
        if not starts:
            return
        rows = np.fromiter(starts.keys(), np.int32)
        st = np.fromiter((starts[r] for r in rows), np.int32)
        attn = dict(self.cache["attn"])
        smax = attn["pos"].shape[1]
        dead = jnp.asarray(np.arange(smax)[None, :] >= st[:, None])
        rows_j = jnp.asarray(rows)
        attn["pos"] = attn["pos"].at[rows_j].set(
            jnp.where(dead, -1, attn["pos"][rows_j]))
        cache = dict(self.cache)
        cache["attn"] = attn
        self.cache = cache

    def reclaim_tail(self, slot: int, keep_upto: int, sync: bool = True) -> int:
        """Unmap mapped blocks wholly past the row's live frontier and
        return them to the free list.  The freed logical slots' pos lanes
        are reset to -1 (they already are after any commit — the reset
        guards direct pool mutations in tests).  ``sync=False`` defers both
        the table push and the pos reset (``reclaim_tails`` batches them)."""
        first = self.blocks_for(keep_upto)
        freed = [i for i in range(first, self.max_blocks) if self._tbl[slot, i] >= 0]
        if not freed:
            return 0
        for i in freed:
            heapq.heappush(self._free_blocks, int(self._tbl[slot, i]))
            self._tbl[slot, i] = -1
        if sync:
            self._sync_tbl()
            self._reset_pos_tails({slot: freed[0] * self.block})
        else:
            self._pending_pos[slot] = min(freed[0] * self.block,
                                          self._pending_pos.get(slot, 1 << 30))
        return len(freed)

    def reclaim_tails(self, frontiers: dict) -> int:
        """Batched ``reclaim_tail`` over {slot: keep_upto}: one device table
        push and one pos-reset round for the whole sweep."""
        self._pending_pos = {}
        freed = sum(self.reclaim_tail(s, keep, sync=False) for s, keep in frontiers.items())
        if freed:
            self._sync_tbl()
            self._reset_pos_tails(self._pending_pos)
        self._pending_pos = {}
        return freed

    def release(self, slot: int) -> None:
        owned = self._tbl[slot][self._tbl[slot] >= 0]
        if owned.size:
            for b in owned:
                heapq.heappush(self._free_blocks, int(b))
            self._tbl[slot] = -1
            self._sync_tbl()
        super().release(slot)

    def admit(self, row_cache: dict, ctx_len: int = 0) -> int:
        """Acquire a row, map blocks for the prefilled context, scatter the
        dense row through the table.  Callers gate on ``free_blocks`` first;
        an exhausted free list here is a scheduling bug."""
        slot = self.acquire()
        if not self.ensure(slot, ctx_len):
            super().release(slot)
            raise RuntimeError(
                f"paged pool out of blocks admitting a {ctx_len}-token context "
                f"({self.free_blocks} free)"
            )
        self.cache = scatter_streams(self.cache, row_cache, [slot])
        return slot


def make_cache_pool(cache: dict, n_slots: int, sharding=None) -> CachePool:
    """Pool factory: paged pools for paged caches, ring pools otherwise
    (pure-recurrent caches have no attn component to page).  ``sharding``
    (a ``launch.sharding.pool_shardings`` pytree) commits the pool arrays
    to the mesh data axis at construction."""
    cls = PagedCachePool if is_paged(cache) else CachePool
    return cls(cache, n_slots, sharding=sharding)
