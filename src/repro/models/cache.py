"""KV / recurrent-state cache structures.

Attention caches are ring buffers of size ``Smax`` (= window for
sliding-window archs): slot = position % Smax, with absolute positions stored
so masks can express both causality and the sliding window uniformly.

Two layouts:

  * lockstep (``per_stream=False``): all requests advance together, so
    ``len`` and ``pos`` are shared across the batch (the training / dryrun
    shapes, and the single-stream engine).
  * per-stream (``per_stream=True``): ``len`` is (B,) and ``pos`` is
    (B, Smax) so every batch row holds an independent stream at its own
    sequence position.  This is the substrate of the continuous-batching
    engine: rows join/leave a fixed-capacity pool without recompiles.

Layout (leading layer axis L, scanned):
    attn:  k, v: (L, B, Smax, Hkv, hd);  pos: (Smax,) or (B, Smax) int32;
           len: () or (B,) int32
    ssm:   state: (L, B, H, P, N); conv: (L, B, K-1, C);  len: () or (B,)
    rglru: state: (L, B, D); conv: (L, B, 3, D);          len: () or (B,)

Ring-compaction commit contract (serving/serve_step.make_pool_commit_step):
a tree pass appends a block of Tpad speculative tokens at slots
(C + t) % Smax for t = 0..Tpad-1, where C is the row's committed length
before the block (so the pending root token sits at slot C % Smax).
Committing an accepted node path [n_1 < n_2 < ... < n_tau] then

  * moves KV lanes  (C + n_j) % Smax  ->  (C + j) % Smax  for j = 1..tau
    (dst slots are the contiguous run C+1 .. C+tau);
  * invalidates every block slot: pos[(C + t) % Smax] = -1 for the whole
    padded block, for every layer-shared pos table of the row;
  * rewrites pos over the surviving run: pos[(C + j) % Smax] = C + j for
    j = 0..tau (the root at C stays committed);
  * advances the row's len to C + 1 + tau.

Accepted node indices are strictly increasing with n_j >= j + 1 (deeper
tree nodes are always appended later), so a source slot is never an
EARLIER entry's destination (n_j = i + 1 needs i >= j) and destinations
are pairwise distinct: every entry reads its pre-commit value, making the
sequential in-place copy (kernels/commit_kv.py) exactly gather-then-
scatter.  Ragged paths pad with identity copies of the root slot, which
no real entry writes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_attn_cache(cfg, n_layers: int, batch: int, smax: int, dtype, per_stream: bool = False):
    hd = cfg.hd
    return {
        "k": jnp.zeros((n_layers, batch, smax, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((n_layers, batch, smax, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((batch, smax) if per_stream else (smax,), -1, jnp.int32),
        "len": jnp.zeros((batch,) if per_stream else (), jnp.int32),
    }


def cache_slots(length: jax.Array, T: int, smax: int) -> jax.Array:
    """(T,) slots for scalar length; (B, T) for per-stream (B,) lengths."""
    off = jnp.arange(T, dtype=jnp.int32)
    if getattr(length, "ndim", 0) == 1:
        return (length[:, None] + off[None, :]) % smax
    return (length + off) % smax


def append_layer_kv(k_cache, v_cache, k_new, v_new, slots):
    """k_cache: (B, Smax, Hkv, hd); k_new: (B, T, Hkv, hd);
    slots: (T,) shared or (B, T) per stream."""
    if slots.ndim == 2:
        b = jnp.arange(k_cache.shape[0])[:, None]
        return (
            k_cache.at[b, slots].set(k_new.astype(k_cache.dtype)),
            v_cache.at[b, slots].set(v_new.astype(v_cache.dtype)),
        )
    return k_cache.at[:, slots].set(k_new.astype(k_cache.dtype)), v_cache.at[:, slots].set(
        v_new.astype(v_cache.dtype)
    )


def attn_mask_from_pos(pos: jax.Array, q_positions: jax.Array, window: int = 0) -> jax.Array:
    """Mask: slot valid iff 0 <= pos[s] <= q_pos[t] (and within the window
    when sliding).  pos: (Smax,) or (B, Smax); q_positions: (T,) or (B, T)
    absolute positions of queries.  Returns (1, 1, T, Smax) or
    (B, 1, T, Smax)."""
    s = pos[..., None, :]
    t = q_positions[..., :, None]
    m = (s >= 0) & (s <= t)
    if window:
        m = m & (s > t - window)
    return m[:, None] if m.ndim == 3 else m[None, None]


def tree_mask_from_pos(
    pos: jax.Array, q_positions: jax.Array, anc: jax.Array, self_slots: jax.Array, window: int = 0
) -> jax.Array:
    """Tree-pass mask over cache slots that now *contain* the tree tokens.

    The T tree tokens were appended into ``self_slots``; a tree token may
    attend to (a) any older cache slot per the causal/window rule against the
    *branch-context* boundary, and (b) its tree ancestors (anc, (T, T) or
    per-stream (B, T, T), including self).
    """
    if pos.ndim == 2:  # per-stream tables: pos (B, Smax), self_slots (B, T)
        B, T = self_slots.shape
        base = attn_mask_from_pos(pos, q_positions, window)[:, 0]  # (B, T, Smax)
        bidx = jnp.arange(B)[:, None]
        is_self = jnp.zeros(pos.shape, bool).at[bidx, self_slots].set(True)  # (B, Smax)
        base = base & ~is_self[:, None, :]
        anc_b = anc if anc.ndim == 3 else jnp.broadcast_to(anc[None], (B, T, T))
        tree_part = (
            jnp.zeros(base.shape, bool)
            .at[bidx[:, :, None], jnp.arange(T)[None, :, None], self_slots[:, None, :]]
            .set(anc_b.astype(bool))
        )
        return (base | tree_part)[:, None]  # (B, 1, T, Smax)
    base = attn_mask_from_pos(pos, q_positions, window)[0, 0]  # (T, Smax)
    # cut out the tree's own slots from the causal rule, then re-add ancestors
    is_self = jnp.zeros(pos.shape, bool).at[self_slots].set(True)  # (Smax,)
    base = base & ~is_self[None, :]
    if anc.ndim == 3:  # batched ancestor masks (B, T, T), shared slot table
        tree_part = (
            jnp.zeros((anc.shape[0],) + base.shape, bool)
            .at[:, :, self_slots]
            .set(anc.astype(bool))
        )
        return (base[None] | tree_part)[:, None]  # (B, 1, T, Smax)
    tree_part = jnp.zeros(base.shape, bool).at[:, self_slots].set(anc.astype(bool))
    return (base | tree_part)[None, None]  # (1, 1, T, Smax)


# ---------------------------------------------------------- stream algebra ---
#
# Every cache array has at most one "stream" axis (the batch axis).  Its
# position depends on the array family; the walker below encodes that map
# once so fork/gather/scatter/merge work for every architecture.

_AXIS1 = ("state", "conv", "tail_state", "tail_conv", "cross_k", "cross_v")


def _walk(cache, other, fn):
    """Apply fn(dst, src, axis) over the cache pytree; axis None for arrays
    without a stream axis (lockstep pos/len)."""
    out = {}
    for key, val in cache.items():
        o = other[key] if other is not None else None
        if key == "attn":
            a = {}
            a["k"] = fn(val["k"], o["k"] if o else None, 1)
            a["v"] = fn(val["v"], o["v"] if o else None, 1)
            a["pos"] = fn(val["pos"], o["pos"] if o else None, 0 if val["pos"].ndim == 2 else None)
            a["len"] = fn(val["len"], o["len"] if o else None, 0 if val["len"].ndim == 1 else None)
            out[key] = a
        elif key in ("rec_state", "rec_conv"):
            out[key] = fn(val, o, 2)
        elif key in _AXIS1:
            out[key] = fn(val, o, 1)
        elif key == "len":
            out[key] = fn(val, o, 0 if val.ndim == 1 else None)
        else:
            out[key] = fn(val, o, None)
    return out


def fork_streams(cache: dict, K: int) -> dict:
    """Replicate every stream row K times along its stream axis (row b maps
    to rows b*K .. b*K+K-1).  Lockstep pos/len are shared, not replicated."""
    return _walk(cache, None, lambda a, _, ax: a if ax is None else jnp.repeat(a, K, axis=ax))


def gather_streams(cache: dict, rows) -> dict:
    """Select stream rows (a smaller cache over ``rows``, in order)."""
    rows = jnp.asarray(rows)
    return _walk(cache, None, lambda a, _, ax: a if ax is None else jnp.take(a, rows, axis=ax))


def scatter_streams(pool: dict, rows_cache: dict, slots) -> dict:
    """Write ``rows_cache`` stream rows into ``pool`` at ``slots`` (list of
    pool row indices, one per rows_cache row)."""
    slots = jnp.asarray(slots)

    def put(dst, src, ax):
        if ax is None:
            return dst
        dst_m = jnp.moveaxis(dst, ax, 0)
        src_m = jnp.moveaxis(src, ax, 0).astype(dst_m.dtype)
        return jnp.moveaxis(dst_m.at[slots].set(src_m), 0, ax)

    return _walk(pool, rows_cache, put)


def concat_streams(caches: list[dict]) -> dict:
    """Concatenate several per-stream caches along their stream axis.

    Used to fuse a step's row-sized sub-caches (one per length group) into a
    single rows-cache so the pool write-back is ONE scatter_streams call
    instead of one full-pool copy per group.  Arrays without a stream axis
    (lockstep pos/len) are taken from the first cache.
    """
    axes = _walk(caches[0], None, lambda a, _, ax: ax)

    def rec(vals, ax):
        if isinstance(vals[0], dict):
            return {key: rec([v[key] for v in vals], ax[key]) for key in vals[0]}
        if ax is None:
            return vals[0]
        return jnp.concatenate(vals, axis=ax)

    return rec(list(caches), axes)


def merge_streams(new: dict, old: dict, keep) -> dict:
    """Per-stream select: row b of the result is ``new``'s where keep[b],
    else ``old``'s.  The freeze primitive of padded lockstep stepping: rows
    whose stream has no real token this step keep their exact prior state."""
    keep = jnp.asarray(keep)

    def sel(n, o, ax):
        if ax is None:
            return n
        shape = [1] * n.ndim
        shape[ax] = keep.shape[0]
        return jnp.where(keep.reshape(shape), n, o)

    return _walk(new, old, sel)


class CachePool:
    """Fixed-capacity slot pool over a per-stream cache.

    Holds one batched cache of ``n_slots`` rows plus free-slot bookkeeping so
    streams can join (prefill a 1-row cache, scatter it in) and leave
    (release the slot) without any recompilation: every model call sees the
    same (n_slots, ...) shapes.
    """

    def __init__(self, cache: dict, n_slots: int):
        self.cache = cache
        self.n_slots = n_slots
        self._free = list(range(n_slots))

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def acquire(self) -> int:
        if not self._free:
            raise RuntimeError("cache pool exhausted")
        return self._free.pop(0)

    def release(self, slot: int) -> None:
        assert slot not in self._free
        self._free.append(slot)
        self._free.sort()

    def admit(self, row_cache: dict) -> int:
        """Scatter a freshly prefilled 1-row per-stream cache into a free slot."""
        slot = self.acquire()
        self.cache = scatter_streams(self.cache, row_cache, [slot])
        return slot
