"""Unified model configuration covering all assigned architecture families.

One dataclass drives: dense GQA decoders (llama/granite/qwen style), MoE,
Mamba-2 SSD, RG-LRU hybrids (RecurrentGemma), encoder-decoder (Whisper
backbone) and VLM early-fusion decoders (InternVL backbone).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    arch_type: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    vocab: int = 1024
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # attention variant: "full" or "sliding_window" (used for long-context
    # decode on otherwise-quadratic archs; see DESIGN.md)
    attention: str = "full"
    window: int = 8192
    # attention implementation: "xla" (jnp einsum; SPMD-friendly, default) or
    # "pallas" (the kernels/ masked-flash kernel; head_dim must be 128 on
    # real TPUs; interpret=True executes on CPU for validation)
    attention_impl: str = "xla"
    kernel_interpret: bool = True

    # MoE — inference routing is dropless (exactness; see models/moe.py);
    # capacity_factor bounds the training dispatch buffers only
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # interleaved MoE (Llama-4 style): every ``moe_every``-th layer is MoE,
    # the rest are dense with ``moe_dense_ff`` FFN width (0 -> d_ff)
    moe_every: int = 1
    moe_dense_ff: int = 0

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_conv: int = 4
    ssm_groups: int = 1

    # hybrid (RG-LRU): pattern "rr a" repeated — attn_every = 3 means layers
    # [rec, rec, attn, rec, rec, attn, ...]; local attention window below.
    hybrid_attn_every: int = 3
    lru_width: int = 0  # 0 -> d_model
    local_window: int = 2048
    # Griffin uses block-diagonal recurrence/input gates; 16 blocks also makes
    # the gates communication-free under 16-way tensor parallelism (§Perf)
    lru_blocks: int = 16

    # encoder-decoder (Whisper backbone): encoder config mirrors decoder dims
    n_enc_layers: int = 0
    enc_len: int = 1500  # precomputed audio frame embeddings (stub frontend)

    # VLM early fusion: number of patch embeddings prepended (stub frontend)
    n_patches: int = 0

    # rematerialise layer activations during training (backward recompute);
    # essential for the large configs to fit HBM at train_4k
    remat: bool = True

    # scan over layers (compile-time O(1) in depth).  The roofline harness
    # unrolls (scan=False) small-L variants because XLA's cost analysis
    # counts while-loop bodies once, ignoring trip counts.
    scan: bool = True

    # source citation for assigned configs
    source: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def lru_d(self) -> int:
        return self.lru_width if self.lru_width else self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, f, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.hd
        emb = V * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        mlp = 3 * d * f
        if self.arch_type == "moe":
            moe_mlp = self.n_experts * 3 * d * f + d * self.n_experts
            if self.moe_every > 1:
                n_moe = L // self.moe_every
                dense_ff = self.moe_dense_ff or f
                blocks = (
                    n_moe * (attn + moe_mlp + 2 * d)
                    + (L - n_moe) * (attn + 3 * d * dense_ff + 2 * d)
                )
                return emb + blocks
            mlp = moe_mlp
        if self.arch_type == "ssm":
            di, ns = self.d_inner, self.ssm_state
            blk = d * (2 * di + 2 * self.ssm_groups * ns + self.ssm_heads) + di * d
            return emb + L * (blk + d)
        if self.arch_type == "hybrid":
            dl = self.lru_d
            # w_x, w_y, w_out dense + block-diagonal gates + conv
            rec = d * dl * 2 + dl * d + 2 * dl * dl // max(self.lru_blocks, 1) + 6 * dl
            n_attn = L // self.hybrid_attn_every
            n_rec = L - n_attn
            return emb + n_rec * (rec + mlp + 2 * d) + n_attn * (attn + mlp + 2 * d)
        blocks = L * (attn + mlp + 2 * d)
        if self.arch_type == "encdec":
            blocks += self.n_enc_layers * (attn + mlp + 2 * d) + L * attn  # cross-attn
        return emb + blocks

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if self.arch_type != "moe":
            return self.param_count()
        d, f, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.hd
        emb = V * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        mlp = self.top_k * 3 * d * f + d * self.n_experts
        if self.moe_every > 1:
            n_moe = L // self.moe_every
            dense_ff = self.moe_dense_ff or f
            return emb + n_moe * (attn + mlp + 2 * d) + (L - n_moe) * (
                attn + 3 * d * dense_ff + 2 * d
            )
        return emb + L * (attn + mlp + 2 * d)
