"""Shared neural layers: RMSNorm, RoPE, GQA attention (full / sliding-window /
decode / tree modes), SwiGLU MLP, embeddings.

All functions are pure; parameters are plain pytrees.  Attention is written
against an explicit additive mask so the same code path serves training
(causal), prefill, single-token decode against a KV cache, and the
speculative *tree pass* (ancestor mask within the speculation block).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return ((x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)) * (1.0 + scale)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """Rotary embedding.  x: (..., T, H, D); positions: (..., T)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., T, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def init_dense(key, din, dout, dtype, scale=None):
    s = scale if scale is not None else 1.0 / np.sqrt(din)
    return (jax.random.normal(key, (din, dout), jnp.float32) * s).astype(dtype)


def attention_weights_init(cfg, key):
    hd = cfg.hd
    ks = jax.random.split(key, 5)
    dt = cfg.jdtype
    p = {
        "wq": init_dense(ks[0], cfg.d_model, cfg.n_heads * hd, dt),
        "wk": init_dense(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dt),
        "wv": init_dense(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dt),
        "wo": init_dense(ks[3], cfg.n_heads * hd, cfg.d_model, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
    return p


def gqa_attend(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array | None) -> jax.Array:
    """Grouped-query attention core.

    q: (B, T, H, D);  k, v: (B, S, Hkv, D);  mask: broadcastable to
    (B, 1, T, S) boolean (True = attend) or None.
    Returns (B, T, H, D).
    """
    B, T, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, T, Hkv, G, D)
    logits = jnp.einsum("bthgd,bshd->bhgts", qg, k).astype(jnp.float32) / np.sqrt(D)
    if mask is not None:
        logits = jnp.where(mask[:, :, None, :, :] if mask.ndim == 4 else mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgts,bshd->bthgd", w.astype(v.dtype), v)
    return out.reshape(B, T, H, D)


def causal_mask(T: int, window: int = 0) -> jax.Array:
    i = jnp.arange(T)[:, None]
    j = jnp.arange(T)[None, :]
    m = j <= i
    if window:
        m = m & (i - j < window)
    return m[None, None]  # (1, 1, T, T)


def decode_mask(S: int, cache_len: jax.Array, window: int = 0) -> jax.Array:
    """Mask for T query tokens appended after cache_len context tokens.
    Valid key positions: j < cache_len (+ window constraint handled by the
    caller's position arithmetic for ring caches)."""
    j = jnp.arange(S)[None, :]
    m = j < cache_len[:, None] if cache_len.ndim else j < cache_len
    return m[:, None, None, :] if m.ndim == 2 else m[None, None, None, :]


def tree_pass_mask(S: int, cache_len: jax.Array, anc: jax.Array) -> jax.Array:
    """Mask for a speculative tree pass: T tree tokens attend to (a) all cache
    positions < cache_len and (b) tree ancestors per anc (B?, T, T) or (T, T).

    Returns (B, 1, T, S + T) given anc (B, T, T), or (1, 1, T, S+T) for (T, T).
    """
    if anc.ndim == 2:
        anc = anc[None]
    B, T, _ = anc.shape
    j = jnp.arange(S)[None, None, :]
    cl = cache_len if getattr(cache_len, "ndim", 0) else jnp.full((B,), cache_len)
    prefix = jnp.broadcast_to(j < cl[:, None, None], (B, T, S))
    full = jnp.concatenate([prefix, anc.astype(bool)], axis=-1)
    return full[:, None]  # (B, 1, T, S+T)


def swiglu_init(cfg, key, d_ff=None):
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.jdtype
    return {
        "w_gate": init_dense(ks[0], cfg.d_model, f, dt),
        "w_up": init_dense(ks[1], cfg.d_model, f, dt),
        "w_down": init_dense(ks[2], f, cfg.d_model, dt),
    }


def swiglu(p, x):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def project_qkv(p, cfg, x):
    hd = cfg.hd
    B, T, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (
        q.reshape(B, T, cfg.n_heads, hd),
        k.reshape(B, T, cfg.n_kv_heads, hd),
        v.reshape(B, T, cfg.n_kv_heads, hd),
    )
