"""Mixture-of-Experts layer with sort-free dropless dispatch.

TPU-native formulation: tokens are scattered into per-expert capacity buffers
(E, C, D) via computed slot indices (rank-within-expert by cumulative count),
expert FFNs run as one batched einsum (E, C, D) x (E, D, F), and outputs are
gathered back with router-probability weighting.  Under a mesh that shards
tokens on the data axis and experts on the model axis, XLA SPMD lowers the
scatter/gather pair to all-to-all collectives — the communication pattern of
expert parallelism.

Inference routing is DROPLESS (Qwen3-MoE style): the per-expert buffer is
sized for the worst-case load, so no (token, choice) is ever dropped.  This
is a correctness requirement, not a tuning choice — capacity-factor dropping
makes a token's output depend on which other tokens share its batch, which
breaks (a) decode/full consistency (the qwen3 decode-consistency failure:
max-logit err ~1.16 came from the last token overflowing a full-pass
capacity buffer it never overflows in a 1-token decode) and (b) the
batch-invariance the batched speculative engine relies on for lossless
multi-stream serving.

Training (``train=True``, set by ``loss_fn``) keeps the standard
capacity-factor dispatch: the worst-case buffer would multiply expert-FFN
compute/memory by ~E/(top_k * capacity_factor) at train_4k scale, and drop
semantics there are a regularisation choice, not a correctness issue.  An
auxiliary load-balancing loss is returned either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import init_dense


def init_moe(cfg, key):
    ks = jax.random.split(key, 4)
    dt = cfg.jdtype
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": init_dense(ks[0], d, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32) / np.sqrt(d)).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32) / np.sqrt(d)).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32) / np.sqrt(f)).astype(dt),
    }


def moe_capacity(n_tokens: int, cfg, train: bool = False) -> int:
    """Per-expert buffer size, padded to an 8-multiple for TPU tiling.

    Inference: dropless — top_k experts of one token are distinct, so the
    worst-case load on any single expert is n_tokens.
    Training: standard capacity-factor bound (overflow is dropped)."""
    if train and cfg.capacity_factor > 0:
        c = int(np.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    else:
        c = n_tokens
    return max(8, int(np.ceil(c / 8) * 8))


def moe_apply(p, cfg, x: jax.Array, train: bool = False):
    """x: (B, S, D) -> (B, S, D), aux_loss (scalar)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    N = B * S
    C = moe_capacity(N, cfg, train)
    drops = train and cfg.capacity_factor > 0
    xf = x.reshape(N, D)

    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (N, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalised gates

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    # slot assignment: rank of each (token, choice) within its expert.
    # Sort-based (MaxText-style): a stable argsort groups the expert ids, a
    # tiny E-length cumsum gives group starts, and ranks fall out of the
    # sorted positions.  (The one-hot cumsum alternative lowers to
    # O(N*k * window) reduce-windows — 40x the matmul flops at train_4k;
    # see EXPERIMENTS.md §Perf cycle 2.)
    flat_e = top_e.reshape(-1)  # (N*k,)
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    hist = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(hist)[:-1]])
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - starts[flat_e[order]]
    slot = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)
    if drops:
        flat_idx = jnp.where(slot < C, flat_e * C + slot, E * C)  # E*C = drop bin
    else:
        # dropless: rank-within-expert < per-expert load <= N <= C, in range
        flat_idx = flat_e * C + slot

    # dispatch: (E*C (+1 drop-bin row when training), D) buffers.
    # NOTE (§Perf cycle 5, REFUTED): constraining this buffer to 2D
    # (experts -> model, capacity -> data) via act_sharding.pin_moe_buffer
    # made both the memory and collective terms ~2x WORSE at train_4k —
    # the combine gather back from a capacity-sharded buffer forces a full
    # reshard.  XLA's own placement (experts -> model from the weight specs,
    # capacity unsharded) is the better schedule; left as measured.
    src = jnp.repeat(xf, k, axis=0)  # (N*k, D)
    buf = jnp.zeros((E * C + drops, D), x.dtype).at[flat_idx].add(src)
    buf = buf[: E * C].reshape(E, C, D)

    # expert FFN: batched SwiGLU
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["w_up"]
    )
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, D)
    if drops:
        out_buf = jnp.concatenate([out_buf, jnp.zeros((1, D), out_buf.dtype)], axis=0)

    # combine: gather each (token, choice) result and weight by the gate
    gathered = out_buf[flat_idx]  # (N*k, D) — dropped training tokens hit the zero row
    weighted = gathered * top_p.reshape(-1)[:, None].astype(gathered.dtype)
    y = jnp.sum(weighted.reshape(N, k, D), axis=1)
    return y.reshape(B, S, D), aux
