"""Model stacks for every assigned architecture family.

Layers are parameter-stacked (leading L axis) and driven by ``jax.lax.scan``
— the MaxText-style pattern that keeps XLA compile time flat in depth (the
94-layer MoE compiles as one scanned block).  The hybrid (RecurrentGemma)
stack scans over (rec, rec, local-attn) groups.

Three entry points (all pure):
    init_params(cfg, key)
    forward(params, cfg, tokens, ...)         mode: "full" | "decode" | "tree"
    loss_fn(params, cfg, batch)               next-token CE for train_step
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.cache import (
    append_layer_kv,
    attn_mask_from_pos,
    cache_slots,
    init_attn_cache,
    init_paged_attn_cache,
    paged_append_layer_kv,
    paged_layer_view,
    ragged_tree_mask,
    tree_mask_from_pos,
)
from repro.models.layers import (
    attention_weights_init,
    causal_mask,
    gqa_attend,
    init_dense,
    project_qkv,
    rms_norm,
    rope,
    swiglu,
    swiglu_init,
)
from repro.models.act_sharding import pin
from repro.models.moe import init_moe, moe_apply
from repro.models.rglru import init_rglru, rglru_apply
from repro.models.ssm import init_ssm, ssm_apply


# ----------------------------------------------------------------- params ----


def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def _attn_mlp_layer_init(cfg, key, cross: bool = False, moe: bool = False, d_ff: int | None = None):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": attention_weights_init(cfg, ks[0]),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    p["mlp"] = init_moe(cfg, ks[1]) if moe else swiglu_init(cfg, ks[1], d_ff=d_ff)
    if cross:
        p["ln_x"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["xattn"] = attention_weights_init(cfg, ks[2])
    return p


def init_params(cfg, key) -> dict:
    ks = jax.random.split(key, 8)
    dt = cfg.jdtype
    params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02).astype(dt),
        "final_ln": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(ks[1], cfg.d_model, cfg.vocab, dt)

    if cfg.arch_type in ("dense", "vlm"):
        params["blocks"] = _stack_init(lambda k: _attn_mlp_layer_init(cfg, k), ks[2], cfg.n_layers)
        if cfg.arch_type == "vlm":
            params["patch_proj"] = init_dense(ks[3], cfg.d_model, cfg.d_model, dt)
    elif cfg.arch_type == "moe":
        if cfg.moe_every > 1:
            # interleaved dense/MoE macro-layers (Llama-4 style)
            m = cfg.moe_every
            assert cfg.n_layers % m == 0, "n_layers must divide moe_every"
            dense_ff = cfg.moe_dense_ff or cfg.d_ff

            def macro_init(k):
                kk = jax.random.split(k, m)
                gp = {
                    f"dense{i}": _attn_mlp_layer_init(cfg, kk[i], d_ff=dense_ff)
                    for i in range(m - 1)
                }
                gp["moe"] = _attn_mlp_layer_init(cfg, kk[m - 1], moe=True)
                return gp

            params["blocks"] = _stack_init(macro_init, ks[2], cfg.n_layers // m)
        else:
            params["blocks"] = _stack_init(
                lambda k: _attn_mlp_layer_init(cfg, k, moe=True), ks[2], cfg.n_layers
            )
    elif cfg.arch_type == "ssm":
        params["blocks"] = _stack_init(
            lambda k: {"ln": jnp.zeros((cfg.d_model,), jnp.float32), "ssm": init_ssm(cfg, k)},
            ks[2],
            cfg.n_layers,
        )
    elif cfg.arch_type == "hybrid":
        g = cfg.hybrid_attn_every
        n_groups, rem = divmod(cfg.n_layers, g)

        def group_init(k):
            kk = jax.random.split(k, g)
            gp = {}
            for i in range(g - 1):
                gp[f"rec{i}"] = {
                    "ln": jnp.zeros((cfg.d_model,), jnp.float32),
                    "rec": init_rglru(cfg, kk[i]),
                    "ln_m": jnp.zeros((cfg.d_model,), jnp.float32),
                    "mlp": swiglu_init(cfg, kk[i]),
                }
            gp["attn"] = _attn_mlp_layer_init(cfg, kk[g - 1])
            return gp

        params["blocks"] = _stack_init(group_init, ks[2], n_groups)
        if rem:
            params["tail"] = _stack_init(
                lambda k: {
                    "ln": jnp.zeros((cfg.d_model,), jnp.float32),
                    "rec": init_rglru(cfg, k),
                    "ln_m": jnp.zeros((cfg.d_model,), jnp.float32),
                    "mlp": swiglu_init(cfg, k),
                },
                ks[3],
                rem,
            )
    elif cfg.arch_type == "encdec":
        params["enc_blocks"] = _stack_init(
            lambda k: _attn_mlp_layer_init(cfg, k), ks[2], cfg.n_enc_layers
        )
        params["enc_ln"] = jnp.zeros((cfg.d_model,), jnp.float32)
        params["blocks"] = _stack_init(
            lambda k: _attn_mlp_layer_init(cfg, k, cross=True), ks[3], cfg.n_layers
        )
    else:
        raise ValueError(cfg.arch_type)
    return params


# ----------------------------------------------------------------- blocks ----


def _self_attention(p, cfg, x, positions, mask, layer_cache, window, ragged=None):
    """Shared attention sub-block.  layer_cache: None or (k, v, slots, page)
    with page = None (dense cache) or the (B, max_blocks) block table of a
    paged pool (models/cache.py paged layout).

    ragged: None, or the (N,) owner-row vector of the ragged node-major tree
    pass (see forward).  Then x is (1, N, d), ``slots`` are per-NODE ring
    slots in the owner's row (Smax sentinel = padding lane, dropped), and
    ``mask`` is the (N, 1, 1, Smax) per-node admit mask."""
    B, T, _ = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = project_qkv(p["attn"], cfg, h)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    new_kv = None
    page_tbl = None
    if ragged is not None:
        owner = ragged
        kc, vc, slots, page_tbl = layer_cache
        if page_tbl is None:
            kc = kc.at[owner, slots].set(k[0].astype(kc.dtype), mode="drop")
            vc = vc.at[owner, slots].set(v[0].astype(vc.dtype), mode="drop")
        else:
            # scatter each node into its owner's mapped physical lane; padding
            # lanes (slot sentinel) and unmapped blocks route out of range
            block = kc.shape[1]
            smax_l = page_tbl.shape[1] * block
            blk = page_tbl[owner, jnp.minimum(slots, smax_l - 1) // block]
            lanes = kc.shape[0] * block
            phys = jnp.where((slots < smax_l) & (blk >= 0), blk * block + slots % block, lanes)
            kf = kc.reshape((lanes,) + kc.shape[2:])
            vf = vc.reshape((lanes,) + vc.shape[2:])
            kc = kf.at[phys].set(k[0].astype(kc.dtype), mode="drop").reshape(kc.shape)
            vc = vf.at[phys].set(v[0].astype(vc.dtype), mode="drop").reshape(vc.shape)
        new_kv = (kc, vc)
        N = x.shape[1]
        if cfg.attention_impl == "pallas" and page_tbl is not None:
            from repro.kernels.ops import gqa_ragged_tree_attention

            att = gqa_ragged_tree_attention(
                q[0], kc, vc, page_tbl, owner, mask[:, 0, 0],
                interpret=cfg.kernel_interpret,
            )
        else:
            # XLA path: per-node gather of the owner row's logical view
            kd, vd = (kc[owner], vc[owner]) if page_tbl is None else paged_layer_view(
                kc, vc, page_tbl[owner]
            )
            att = gqa_attend(q[0][:, None], kd, vd, mask)[:, 0]
        return x + att.reshape(1, N, -1) @ p["attn"]["wo"], new_kv
    if layer_cache is not None:
        kc, vc, slots, page_tbl = layer_cache
        if page_tbl is None:
            kc, vc = append_layer_kv(kc, vc, k, v, slots)
            k, v = kc, vc
        else:
            kc, vc = paged_append_layer_kv(kc, vc, k, v, slots, page_tbl)
            if not (cfg.attention_impl == "pallas" and mask is not None):
                # XLA reference path: materialize the logical per-stream view
                # (unmapped lanes masked by pos = -1 upstream)
                k, v = paged_layer_view(kc, vc, page_tbl)
        new_kv = (kc, vc)
    if cfg.attention_impl == "pallas" and mask is not None:
        m3 = mask[:, 0] if mask.ndim == 4 else mask
        if page_tbl is not None:
            from repro.kernels.ops import gqa_paged_tree_attention

            att = gqa_paged_tree_attention(q, kc, vc, page_tbl, m3,
                                           interpret=cfg.kernel_interpret)
        else:
            from repro.kernels.ops import gqa_tree_attention

            att = gqa_tree_attention(q, k, v, m3, interpret=cfg.kernel_interpret)
    else:
        att = gqa_attend(q, k, v, mask)
    return x + att.reshape(B, T, -1) @ p["attn"]["wo"], new_kv


def _attn_mlp_block(p, cfg, x, positions, mask, layer_cache, window, moe=False, enc_kv=None,
                    train=False, ragged=None):
    x = pin(x)
    x, new_kv = _self_attention(p, cfg, x, positions, mask, layer_cache, window, ragged=ragged)
    aux = jnp.zeros((), jnp.float32)
    if enc_kv is not None:  # cross attention (enc-dec)
        B, T, _ = x.shape
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        hd = cfg.hd
        q = (h @ p["xattn"]["wq"]).reshape(B, T, cfg.n_heads, hd)
        att = gqa_attend(q, enc_kv[0], enc_kv[1], None)
        x = x + att.reshape(B, T, -1) @ p["xattn"]["wo"]
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if moe:
        y, aux = moe_apply(p["mlp"], cfg, h, train=train)
    else:
        y = swiglu(p["mlp"], h)
    return x + y, new_kv, aux


def _rec_block(p, cfg, x, cache):
    x = pin(x)
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    y, new_cache = rglru_apply(p["rec"], cfg, h, cache)
    x = x + y
    h = rms_norm(x, p["ln_m"], cfg.norm_eps)
    return x + swiglu(p["mlp"], h), new_cache


# ---------------------------------------------------------------- forward ----


def _attn_cache_out(k, v, pos, length, page_tbl):
    """Post-scan attn cache dict; paged pools keep their block table."""
    out = {"k": k, "v": v, "pos": pos, "len": length}
    if page_tbl is not None:
        out["block_tbl"] = page_tbl
    return out



def _pyscan(body, init, xs):
    """Python-unrolled scan (same semantics as lax.scan for our bodies)."""
    n = len(jax.tree.leaves(xs)[0]) if jax.tree.leaves(xs) else 0
    carry = init
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and all(y is not None for y in jax.tree.leaves(ys[0], is_leaf=lambda z: z is None)):
        try:
            ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
        except Exception:
            pass
    else:
        ys = None
    return carry, ys


def _mk_masks(cfg, mode, T, pos, positions, anc, slots):
    """Masks for full-attn layers and (hybrid) local-window layers.

    ``pos`` is the slot->absolute-position table *after* writing the new
    tokens, so queries can see themselves and each other causally.
    """
    win = cfg.window if cfg.attention == "sliding_window" else 0
    if mode == "full":
        return causal_mask(T, win), causal_mask(T, cfg.local_window)
    if mode == "decode":
        return (
            attn_mask_from_pos(pos, positions, win),
            attn_mask_from_pos(pos, positions, cfg.local_window),
        )
    return (
        tree_mask_from_pos(pos, positions, anc, slots, win),
        tree_mask_from_pos(pos, positions, anc, slots, cfg.local_window),
    )


def forward(
    params,
    cfg,
    tokens: jax.Array | None,
    *,
    mode: str = "full",
    cache: dict | None = None,
    anc: jax.Array | None = None,
    embeds: jax.Array | None = None,
    enc_embeds: jax.Array | None = None,
    lens: jax.Array | None = None,
    train: bool = False,
    ragged: dict | None = None,
):
    """Returns (logits, new_cache, aux).

    mode "full":   causal pass over tokens (training / prefill); if ``cache``
                   is given it is filled (prefill), else no cache is built.
    mode "decode": T new tokens against the cache (T=1 for serve_step).
    mode "tree":   T speculation-tree tokens with ancestor mask ``anc``.
    embeds:        pre-computed modality embeddings — VLM patches (prepended
                   at "full" time) or a direct replacement for token embeds.
    enc_embeds:    encoder-side frame embeddings (encdec only).
    lens:          per-stream real-token counts (B,) for *padded* cached
                   passes over a per-stream cache (see models/cache.py):
                   row b's tokens beyond lens[b] are padding — their cache
                   slots are written but marked invalid (pos = -1) and the
                   row's length advances by lens[b] only, so the next append
                   overwrites them.  Requires a per-stream cache.  Note this
                   masks *attention state only*; recurrent (ssm/rglru) state
                   integrates every token, so recurrent-arch callers must
                   keep padded rows frozen via cache.merge_streams instead.
    train:         training semantics (set by loss_fn): MoE uses the bounded
                   capacity-factor dispatch instead of the exact dropless
                   one (see models/moe.py).
    ragged:        node-major ragged tree pass (mode "tree" only; replaces
                   ``anc``).  ``tokens`` is (1, N): every active stream's
                   tree flattened into one node buffer.  Dict keys, each
                   (N,) int32 except counts: ``owner`` node->pool-row,
                   ``parent`` flat-index parent (-1 root/padding),
                   ``depth`` node depth in its tree, ``local`` node index
                   within its tree (-1 padding lane), ``counts`` (B,) real
                   nodes appended per row this pass (0 idle).  Padding
                   lanes write nothing (slot sentinel + drop scatters) and
                   attend to nothing.  Requires a per-stream attn cache and
                   arch_type dense/moe.  See docs/serving.md.
    """
    dt = cfg.jdtype
    if tokens is not None:
        x = params["embed"][tokens].astype(dt)
    else:
        x = embeds.astype(dt)
    if cfg.arch_type == "vlm" and embeds is not None and tokens is not None:
        patches = (embeds.astype(dt) @ params["patch_proj"]).astype(dt)
        x = jnp.concatenate([patches, x], axis=1)
    B, T, _ = x.shape

    length = cache["attn"]["len"] if (cache is not None and "attn" in cache) else (
        cache["len"] if cache is not None else jnp.zeros((), jnp.int32)
    )
    per_stream = getattr(length, "ndim", 0) == 1
    q_pos = None
    if ragged is not None:
        assert mode == "tree" and anc is None and lens is None
        assert per_stream and cfg.arch_type in ("dense", "moe")
        q_pos = length[ragged["owner"]] + ragged["depth"]  # (N,) absolute pos
        positions = q_pos[None, :]  # rope over the node axis (B=1, T=N)
    else:
        offs = jnp.arange(T, dtype=jnp.int32) if anc is None else _tree_depths(anc, per_stream)
        if per_stream:
            positions = length[:, None] + (offs if offs.ndim == 2 else offs[None, :])
        else:
            positions = length + offs
    aux_total = jnp.zeros((), jnp.float32)

    # ---------------- encoder (encdec) ----------------
    enc_kv_all = None
    if cfg.arch_type == "encdec":
        if enc_embeds is None:
            # decode steps: encoder states were projected + cached at prefill
            enc_kv_all = (cache["cross_k"], cache["cross_v"])
        else:
            enc = enc_embeds.astype(dt)

            def enc_body(h, pl):
                h, _, _ = _attn_mlp_block(
                    pl, cfg, h, jnp.arange(h.shape[1], dtype=jnp.int32), None, None, 0
                )
                return h, None

            enc, _ = jax.lax.scan(jax.checkpoint(enc_body) if cfg.remat and cache is None else enc_body, enc, params["enc_blocks"])
            enc = rms_norm(enc, params["enc_ln"], cfg.norm_eps)
            hd = cfg.hd

            def cross_kv(pl):
                k = (enc @ pl["xattn"]["wk"]).reshape(B, -1, cfg.n_kv_heads, hd)
                v = (enc @ pl["xattn"]["wv"]).reshape(B, -1, cfg.n_kv_heads, hd)
                return k, v

            enc_kv_all = jax.vmap(cross_kv)(params["blocks"])

    # ---------------- masks & cache slots ----------------
    use_cache = cache is not None
    has_attn = cfg.arch_type != "ssm"
    slots = new_pos = new_len = None
    page_tbl = None
    mask_full = mask_local = None
    if use_cache and mode == "full":
        mode = "decode"  # prefill == appending T tokens causally to an empty cache
    if has_attn:
        if use_cache and "attn" in cache and ragged is not None:
            page_tbl = cache["attn"].get("block_tbl")
            smax = cache["attn"]["pos"].shape[-1]
            owner = ragged["owner"]
            # node i's ring slot in its owner's row — identical to padded
            # column local[i]'s slot, so commit arithmetic is unchanged.
            # Padding lanes (local < 0) get the always-out-of-range sentinel
            # smax: every .at[...].set(mode="drop") write vanishes.
            slots = jnp.where(
                ragged["local"] >= 0,
                (length[owner] + jnp.maximum(ragged["local"], 0)) % smax,
                smax,
            )
            new_pos = cache["attn"]["pos"].at[owner, slots].set(q_pos, mode="drop")
            new_len = length + ragged["counts"]  # idle rows advance by 0
            win = cfg.window if cfg.attention == "sliding_window" else 0
            mask_full = ragged_tree_mask(
                new_pos, q_pos, owner, slots, ragged["parent"], win
            )[:, None, None, :]  # (N, 1, 1, Smax)
            mask_local = mask_full  # unused: dense/moe only
        elif use_cache and "attn" in cache:
            # paged pools keep logical capacity in the pos table; the KV
            # array's slot axis is the physical block size there
            page_tbl = cache["attn"].get("block_tbl")
            smax = cache["attn"]["pos"].shape[-1]
            slots = cache_slots(length, T, smax)
            pos_vals = positions
            if lens is not None:
                valid = jnp.arange(T, dtype=jnp.int32)[None, :] < lens[:, None]
                pos_vals = jnp.where(valid, positions, -1)
            if per_stream:
                bidx = jnp.arange(slots.shape[0])[:, None]
                new_pos = cache["attn"]["pos"].at[bidx, slots].set(pos_vals)
            else:
                new_pos = cache["attn"]["pos"].at[slots].set(pos_vals)
            new_len = length + (T if lens is None else lens)
            mask_full, mask_local = _mk_masks(cfg, mode, T, new_pos, positions, anc, slots)
        else:
            mask_full, mask_local = _mk_masks(cfg, "full", T, None, None, None, None)

    # ---------------- decoder stacks ----------------
    ragged_owner = ragged["owner"] if ragged is not None else None
    new_cache = dict(cache) if use_cache else None
    # activation checkpointing for the training path (backward recompute)
    ckpt = jax.checkpoint if (cfg.remat and not use_cache) else (lambda f: f)
    scan = jax.lax.scan if cfg.scan else _pyscan

    if cfg.arch_type == "moe" and cfg.moe_every > 1:
        # interleaved dense/MoE macro-layers
        m = cfg.moe_every
        ng = cfg.n_layers // m

        def macro_body(h, per):
            pl, lc = per  # lc: None or (k (m,B,S,H,D), v (m,B,S,H,D))
            ks_, vs_ = [], []
            for i in range(m - 1):
                layer_cache = (lc[0][i], lc[1][i], slots, page_tbl) if lc is not None else None
                h, kv, _ = _attn_mlp_block(
                    pl[f"dense{i}"], cfg, h, positions, mask_full, layer_cache, 0,
                    ragged=ragged_owner,
                )
                if kv is not None:
                    ks_.append(kv[0])
                    vs_.append(kv[1])
            layer_cache = (lc[0][m - 1], lc[1][m - 1], slots, page_tbl) if lc is not None else None
            h, kv, aux = _attn_mlp_block(
                pl["moe"], cfg, h, positions, mask_full, layer_cache, 0, moe=True, train=train,
                ragged=ragged_owner,
            )
            if kv is not None:
                ks_.append(kv[0])
                vs_.append(kv[1])
            out_kv = (jnp.stack(ks_), jnp.stack(vs_)) if ks_ else None
            return h, (out_kv, aux)

        if use_cache:
            kc = cache["attn"]["k"].reshape((ng, m) + cache["attn"]["k"].shape[1:])
            vc = cache["attn"]["v"].reshape((ng, m) + cache["attn"]["v"].shape[1:])
            x, (kvs, auxs) = scan(macro_body, x, (params["blocks"], (kc, vc)))
            new_cache["attn"] = _attn_cache_out(
                kvs[0].reshape((cfg.n_layers,) + kvs[0].shape[2:]),
                kvs[1].reshape((cfg.n_layers,) + kvs[1].shape[2:]),
                new_pos, new_len, page_tbl,
            )
        else:
            def macro_nc(h, pl):
                h, (_, aux) = macro_body(h, (pl, None))
                return h, aux

            x, auxs = scan(ckpt(macro_nc), x, params["blocks"])
        aux_total = jnp.sum(auxs if not isinstance(auxs, tuple) else auxs[1])

    elif cfg.arch_type in ("dense", "vlm", "moe", "encdec"):
        moe = cfg.arch_type == "moe"

        def body(h, per):
            if cfg.arch_type == "encdec":
                pl, lc, ekv = per
            else:
                pl, lc = per
                ekv = None
            layer_cache = (lc[0], lc[1], slots, page_tbl) if lc is not None else None
            h, new_kv, aux = _attn_mlp_block(
                pl, cfg, h, positions, mask_full, layer_cache, 0, moe=moe, enc_kv=ekv,
                train=train, ragged=ragged_owner,
            )
            return h, (new_kv, aux)

        if use_cache:
            xs = (
                (params["blocks"], (cache["attn"]["k"], cache["attn"]["v"]), enc_kv_all)
                if cfg.arch_type == "encdec"
                else (params["blocks"], (cache["attn"]["k"], cache["attn"]["v"]))
            )
            x, (kvs, auxs) = scan(body, x, xs)
            new_cache["attn"] = _attn_cache_out(kvs[0], kvs[1], new_pos, new_len, page_tbl)
            if cfg.arch_type == "encdec" and enc_embeds is not None:
                new_cache["cross_k"], new_cache["cross_v"] = enc_kv_all
        else:
            xs = (
                (params["blocks"], None, enc_kv_all)
                if cfg.arch_type == "encdec"
                else (params["blocks"], None)
            )
            # scan cannot carry None xs: wrap with explicit Nones via partial
            def body_nc(h, per):
                if cfg.arch_type == "encdec":
                    pl, ekv = per
                else:
                    pl, ekv = per, None
                h, _, aux = _attn_mlp_block(
                    pl, cfg, h, positions, mask_full, None, 0, moe=moe, enc_kv=ekv,
                    train=train,
                )
                return h, aux

            scan_xs = (params["blocks"], enc_kv_all) if cfg.arch_type == "encdec" else params["blocks"]
            x, auxs = scan(ckpt(body_nc), x, scan_xs)
        aux_total = jnp.sum(auxs[1] if isinstance(auxs, tuple) else auxs) if moe else aux_total

    elif cfg.arch_type == "ssm":

        def body(h, per):
            pl, lc = per
            hn = rms_norm(h, pl["ln"], cfg.norm_eps)
            y, nc = ssm_apply(pl["ssm"], cfg, hn, lc)
            return h + y, nc

        lc = (
            {"state": cache["state"], "conv": cache["conv"]} if use_cache else None
        )
        if use_cache:
            def body_c(h, per):
                pl, st, cv = per
                h = pin(h)
                hn = rms_norm(h, pl["ln"], cfg.norm_eps)
                y, nc = ssm_apply(pl["ssm"], cfg, hn, {"state": st, "conv": cv})
                return h + y, (nc["state"], nc["conv"])

            x, (sts, cvs) = scan(body_c, x, (params["blocks"], cache["state"], cache["conv"]))
            new_cache.update({"state": sts, "conv": cvs, "len": length + (T if lens is None else lens)})
        else:
            def body_nc(h, pl):
                h = pin(h)
                hn = rms_norm(h, pl["ln"], cfg.norm_eps)
                y, _ = ssm_apply(pl["ssm"], cfg, hn, None)
                return h + y, None

            x, _ = scan(ckpt(body_nc), x, params["blocks"])

    elif cfg.arch_type == "hybrid":
        g = cfg.hybrid_attn_every

        def group_body_c(h, per):
            pl, rec_states, rec_convs, kc, vc = per
            new_states, new_convs = [], []
            for i in range(g - 1):
                h, nc = _rec_block(
                    pl[f"rec{i}"], cfg, h, {"state": rec_states[i], "conv": rec_convs[i]}
                )
                new_states.append(nc["state"])
                new_convs.append(nc["conv"])
            h, new_kv, _ = _attn_mlp_block(
                pl["attn"], cfg, h, positions, mask_local, (kc, vc, slots, page_tbl), cfg.local_window
            )
            return h, (jnp.stack(new_states), jnp.stack(new_convs), new_kv[0], new_kv[1])

        def group_body_nc(h, pl):
            for i in range(g - 1):
                h, _ = _rec_block(pl[f"rec{i}"], cfg, h, None)
            h, _, _ = _attn_mlp_block(pl["attn"], cfg, h, positions, mask_local, None, cfg.local_window)
            return h, None

        if use_cache:
            x, (sts, cvs, ks_, vs_) = scan(
                group_body_c,
                x,
                (
                    params["blocks"],
                    cache["rec_state"],
                    cache["rec_conv"],
                    cache["attn"]["k"],
                    cache["attn"]["v"],
                ),
            )
            new_cache["rec_state"], new_cache["rec_conv"] = sts, cvs
            new_cache["attn"] = _attn_cache_out(ks_, vs_, new_pos, new_len, page_tbl)
        else:
            x, _ = scan(ckpt(group_body_nc), x, params["blocks"])
        if "tail" in params:
            def tail_c(h, per):
                pl, st, cv = per
                h, nc = _rec_block(pl, cfg, h, {"state": st, "conv": cv})
                return h, (nc["state"], nc["conv"])

            def tail_nc(h, pl):
                h, _ = _rec_block(pl, cfg, h, None)
                return h, None

            if use_cache:
                x, (tsts, tcvs) = scan(
                    tail_c, x, (params["tail"], cache["tail_state"], cache["tail_conv"])
                )
                new_cache["tail_state"], new_cache["tail_conv"] = tsts, tcvs
            else:
                x, _ = scan(ckpt(tail_nc), x, params["tail"])
        if use_cache:
            new_cache["len"] = length + (T if lens is None else lens)
    else:
        raise ValueError(cfg.arch_type)

    x = pin(rms_norm(x, params["final_ln"], cfg.norm_eps))
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head).astype(jnp.float32)
    return logits, new_cache, {"aux": aux_total, "hidden": x}


def _tree_depths(anc: jax.Array, per_stream: bool = False) -> jax.Array:
    """Positions offset of tree tokens = (ancestor count - 1).

    Lockstep caches treat a (B, T, T) anc as sharing one topology (depths
    from row 0); per-stream caches get per-row depths (B, T)."""
    if anc.ndim == 3 and per_stream:
        return jnp.sum(anc.astype(jnp.int32), axis=-1) - 1
    a = anc if anc.ndim == 2 else anc[0]
    return jnp.sum(a.astype(jnp.int32), axis=-1) - 1


# ------------------------------------------------------------------ cache ----


def init_cache(cfg, batch: int, smax: int, enc_len: int | None = None, per_stream: bool = False,
               page: tuple[int, int] | None = None) -> dict:
    """Empty decode cache for every architecture family.

    smax: attention cache capacity (== window for sliding-window archs; the
    ring buffer makes longer logical contexts fit in window slots).
    per_stream: per-row pos/len tables so batch rows hold independent streams
    (the continuous-batching layout; see models/cache.py).
    page: (pool_blocks, block_size) — store attention KV as a paged block
    arena instead of per-stream rings: ``pool_blocks`` usable blocks of
    ``block_size`` slots shared by all rows through per-row block tables,
    with ``smax`` staying each row's *logical* capacity (must divide into
    block_size).  Requires per_stream.  Pure-recurrent caches ignore it.
    """
    assert page is None or per_stream, "paged caches are per-stream by construction"
    dt = cfg.jdtype
    hd = cfg.hd

    def attn_cache(n_layers):
        if page is not None:
            return init_paged_attn_cache(cfg, n_layers, batch, page[0], page[1], smax, dt)
        return init_attn_cache(cfg, n_layers, batch, smax, dt, per_stream=per_stream)

    cache: dict = {"len": jnp.zeros((batch,) if per_stream else (), jnp.int32)}
    if cfg.arch_type in ("dense", "vlm", "moe", "encdec"):
        cache["attn"] = attn_cache(cfg.n_layers)
        del cache["len"]
        if cfg.arch_type == "encdec":
            el = enc_len or cfg.enc_len
            cache["cross_k"] = jnp.zeros((cfg.n_layers, batch, el, cfg.n_kv_heads, hd), dt)
            cache["cross_v"] = jnp.zeros((cfg.n_layers, batch, el, cfg.n_kv_heads, hd), dt)
    elif cfg.arch_type == "ssm":
        H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        cache["state"] = jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32)
        cache["conv"] = jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_dim), dt)
    elif cfg.arch_type == "hybrid":
        g = cfg.hybrid_attn_every
        n_groups, rem = divmod(cfg.n_layers, g)
        dl = cfg.lru_d
        cache["rec_state"] = jnp.zeros((n_groups, g - 1, batch, dl), jnp.float32)
        cache["rec_conv"] = jnp.zeros((n_groups, g - 1, batch, 3, dl), dt)
        cache["attn"] = attn_cache(n_groups)
        if rem:
            cache["tail_state"] = jnp.zeros((rem, batch, dl), jnp.float32)
            cache["tail_conv"] = jnp.zeros((rem, batch, 3, dl), dt)
    else:
        raise ValueError(cfg.arch_type)
    return cache


def cache_length(cfg, cache) -> jax.Array:
    return cache["attn"]["len"] if "attn" in cache else cache["len"]


# --------------------------------------------------------------- training ----


def loss_fn(params, cfg, tokens: jax.Array, labels: jax.Array, embeds=None, enc_embeds=None):
    """Next-token cross-entropy (+ MoE aux).  labels < 0 are masked."""
    logits, _, extras = forward(
        params, cfg, tokens, mode="full", embeds=embeds, enc_embeds=enc_embeds, train=True
    )
    aux = extras["aux"]
    if cfg.arch_type == "vlm" and embeds is not None:
        logits = logits[:, embeds.shape[1] :]
    lp = jax.nn.log_softmax(logits, axis=-1)
    mask = labels >= 0
    ll = jnp.take_along_axis(lp, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    ce = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return ce + cfg.router_aux_weight * aux


def make_train_step(cfg, optimizer):
    def train_step(params, opt_state, batch):
        def lf(p):
            return loss_fn(
                p,
                cfg,
                batch["tokens"],
                batch["labels"],
                embeds=batch.get("embeds"),
                enc_embeds=batch.get("enc_embeds"),
            )

        loss, grads = jax.value_and_grad(lf)(params)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step
