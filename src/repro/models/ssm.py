"""Mamba-2 (SSD — state-space duality) block, chunked for TPU.

The SSD recurrence per head h with state (P, N):

    s_t = exp(dt_t * A) * s_{t-1} + dt_t * B_t x_t^T      (outer product)
    y_t = C_t . s_t  + D * x_t

computed with the chunked dual form (arXiv:2405.21060): within a chunk of Q
tokens the contribution is a masked quadratic "attention" with decay kernel
L = exp(segsum(dtA)); across chunks a (cheap) scan propagates the per-chunk
states.  This maps the GPU kernel of the paper onto TPU-friendly einsums —
the chunk dimension gives MXU-shaped matmuls and the cross-chunk scan is a
lax.scan carrying (H, P, N) states.

Decode: the cache is the recurrent state (B, H, P, N) + causal-conv tail
(B, conv-1, d_conv_channels); one step is O(1) in sequence length (this is
why the SSM archs run the 500k-context shape natively).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense


def init_ssm(cfg, key):
    d, di = cfg.d_model, cfg.d_inner
    H, P, N, G = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_groups
    conv_dim = di + 2 * G * N
    ks = jax.random.split(key, 5)
    dt = cfg.jdtype
    return {
        # fused input projection: [z (di), x (di), B (G*N), C (G*N), dt (H)]
        "w_in": init_dense(ks[0], d, 2 * di + 2 * G * N + H, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "w_out": init_dense(ks[2], di, d, dt),
        "norm_z": jnp.zeros((di,), jnp.float32),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, tail: jax.Array | None):
    """Depthwise causal conv along time.  x: (B, S, C); w: (K, C).
    tail: (B, K-1, C) carried state for decode, or None for prefill.
    Returns (y, new_tail)."""
    K = w.shape[0]
    pad = (
        jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
        if tail is None
        else tail.astype(x.dtype)
    )
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K)) + b
    return jax.nn.silu(y), xp[:, -(K - 1) :]


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., Q) -> (..., Q, Q) lower-triangular segment sums
    segsum[i, j] = sum_{j < m <= i} a[m]."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(Q)[:, None]
    j = jnp.arange(Q)[None, :]
    return jnp.where(j <= i, diff, -jnp.inf)


def ssd_chunked(x, dtA, B, C, chunk: int):
    """Chunked SSD scan.

    x:   (b, S, H, P)   head inputs (already dt-scaled by the caller)
    dtA: (b, S, H)      log-decay increments (negative)
    B:   (b, S, G, N)   input maps     C: (b, S, G, N) output maps
    Returns y (b, S, H, P) and final state (b, H, P, N).
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    assert S % chunk == 0, "sequence must be padded to the SSD chunk"
    c = S // chunk
    R = H // G  # heads per group
    xr = x.reshape(b, c, chunk, H, P)
    ar = dtA.reshape(b, c, chunk, H)
    Br = B.reshape(b, c, chunk, G, N)
    Cr = C.reshape(b, c, chunk, G, N)

    # intra-chunk (diagonal) term
    L = jnp.exp(_segsum(ar.transpose(0, 1, 3, 2)))  # (b, c, H, Q, Q)
    CB = jnp.einsum("bcqgn,bcsgn->bcgqs", Cr, Br)  # (b, c, G, Q, Q)
    CB = jnp.repeat(CB, R, axis=2)  # (b, c, H, Q, Q)
    y_diag = jnp.einsum("bchqs,bcshp->bcqhp", CB * L, xr)

    # per-chunk states
    a_cum = jnp.cumsum(ar, axis=2)  # (b, c, Q, H)
    a_tot = a_cum[:, :, -1:, :]  # (b, c, 1, H)
    decay_in = jnp.exp(a_tot - a_cum)  # weight of token q into the chunk state
    Brep = jnp.repeat(Br, R, axis=3) if G != H else Br
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Brep, decay_in, xr)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(a_tot[:, :, 0, :])  # (b, c, H)

    def step(carry, inp):
        s_prev = carry
        s_c, dec = inp
        s_new = s_prev * dec[:, :, None, None] + s_c
        return s_new, s_prev

    init = jnp.zeros((b, H, P, N), x.dtype)
    final, prev_states = jax.lax.scan(
        step, init, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b, c, H, P, N)

    # off-diagonal (carry-in) term
    Crep = jnp.repeat(Cr, R, axis=3) if G != H else Cr
    decay_out = jnp.exp(a_cum)  # (b, c, Q, H)
    y_off = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp", Crep, decay_out, prev_states)

    y = (y_diag + y_off).reshape(b, S, H, P)
    return y, final


def ssm_apply(p, cfg, u: jax.Array, cache: dict | None):
    """Full Mamba-2 mixer.  u: (B, S, d_model).

    cache: None for train/prefill, else {"state": (B,H,P,N), "conv": (B,K-1,C)}
    for O(1) decode (S small, processed recurrently).
    Returns (y, new_cache).
    """
    B_, S, d = u.shape
    di, H, P = cfg.d_inner, cfg.ssm_heads, cfg.ssm_headdim
    G, N = cfg.ssm_groups, cfg.ssm_state
    zxbcdt = u @ p["w_in"]
    z, xBC, dt = jnp.split(zxbcdt, [di, di + di + 2 * G * N], axis=-1)
    conv_tail = cache.get("conv") if cache else None
    xBC, new_tail = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_tail)
    x, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)
    x = x.reshape(B_, S, H, P)
    Bm = Bm.reshape(B_, S, G, N)
    Cm = Cm.reshape(B_, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, S, H)
    A = -jnp.exp(p["A_log"])  # (H,) negative
    dtA = dt * A  # (B, S, H) log-decay
    xdt = x * dt[..., None].astype(x.dtype)

    if cache is None or S > 1:
        pad = (-S) % cfg.ssm_chunk
        if pad:
            xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dtA_p = jnp.pad(dtA, ((0, 0), (0, pad), (0, 0)))
            Bp = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Cp = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        else:
            dtA_p, Bp, Cp = dtA, Bm, Cm
        init_state = cache.get("state") if cache else None
        y, state = ssd_chunked(xdt.astype(jnp.float32), dtA_p, Bp.astype(jnp.float32), Cp.astype(jnp.float32), cfg.ssm_chunk)
        if init_state is not None:
            # carry-in from an existing state: add C_t exp(cumsum dtA) s_init
            a_cs = jnp.cumsum(dtA_p, axis=1)
            Crep = jnp.repeat(Cp, H // G, axis=2) if G != H else Cp
            y = y + jnp.einsum(
                "bqhn,bqh,bhpn->bqhp", Crep.astype(jnp.float32), jnp.exp(a_cs), init_state
            )
            total = jnp.exp(jnp.sum(dtA_p, axis=1))  # (B, H)
            state = state + init_state * total[:, :, None, None]
        y = y[:, :S]
    else:
        # single-step recurrence
        s = cache["state"]  # (B, H, P, N)
        dec = jnp.exp(dtA[:, 0])  # (B, H)
        Brep = jnp.repeat(Bm, H // G, axis=2) if G != H else Bm
        Crep = jnp.repeat(Cm, H // G, axis=2) if G != H else Cm
        s = s * dec[:, :, None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xdt[:, 0].astype(jnp.float32), Brep[:, 0].astype(jnp.float32)
        )
        y = jnp.einsum("bhn,bhpn->bhp", Crep[:, 0].astype(jnp.float32), s)[:, None]
        state = s

    y = y + x.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(B_, S, di).astype(u.dtype)
    # gated RMSNorm (Mamba-2 style)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) * (1.0 + p["norm_z"])).astype(u.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"]
    new_cache = {"state": state, "conv": new_tail}
    return out, new_cache
