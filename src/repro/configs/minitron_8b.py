"""minitron-8b [dense] — pruned Nemotron [arXiv:2407.14679]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab=256000,
    source="arXiv:2407.14679 (Minitron: Compact Language Models via Pruning and Distillation)",
)


def smoke():
    return CONFIG.replace(n_layers=2, d_model=256, n_heads=4, n_kv_heads=1, d_ff=512, vocab=512)
