"""granite-3-2b [dense] — GQA [hf:ibm-granite/granite-3.0-2b-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    arch_type="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=49155,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base",
)


def smoke():
    return CONFIG.replace(n_layers=2, d_model=256, n_heads=4, n_kv_heads=1, d_ff=512, vocab=512)
