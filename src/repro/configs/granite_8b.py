"""granite-8b [dense] — llama-arch code model [arXiv:2405.04324]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    arch_type="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=49152,
    source="arXiv:2405.04324 (Granite Code Models)",
)


def smoke():
    return CONFIG.replace(n_layers=2, d_model=256, n_heads=4, n_kv_heads=1, d_ff=512, vocab=512)
