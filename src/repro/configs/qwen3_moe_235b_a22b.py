"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B
family scaling]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,  # per-expert FFN width
    vocab=151936,
    n_experts=128,
    top_k=8,
    head_dim=128,
    source="hf:Qwen/Qwen3-30B-A3B (Qwen3 MoE family)",
)


def smoke():
    return CONFIG.replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=1, d_ff=128, vocab=512,
        n_experts=4, top_k=2, head_dim=64,
    )
