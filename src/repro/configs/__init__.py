"""Architecture config registry: ``get_config(name)`` / ``get_smoke(name)``.

The ten assigned architectures (each cites its source) plus the paper's own
Llama-3 70B/8B pair.  Full configs are exercised via the dry-run
(ShapeDtypeStruct lowering only); smoke variants run on CPU.
"""
from __future__ import annotations

import importlib

ARCHES = {
    "granite-8b": "granite_8b",
    "minitron-8b": "minitron_8b",
    "granite-3-2b": "granite_3_2b",
    "whisper-medium": "whisper_medium",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen2-72b": "qwen2_72b",
    "mamba2-2.7b": "mamba2_2_7b",
    "internvl2-26b": "internvl2_26b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "paper-llama70b": "paper_llama70b_8b",
}


def _mod(name: str):
    if name not in ARCHES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHES)}")
    return importlib.import_module(f"repro.configs.{ARCHES[name]}")


def get_config(name: str):
    return _mod(name).CONFIG


def get_smoke(name: str):
    return _mod(name).smoke()


def list_arches() -> list[str]:
    return [a for a in ARCHES if a != "paper-llama70b"]
