"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=128,
    ssm_groups=1,
    tie_embeddings=True,
    source="arXiv:2405.21060 (Transformers are SSMs: Mamba-2)",
)


def smoke():
    return CONFIG.replace(
        n_layers=2, d_model=256, vocab=512, ssm_state=32, ssm_headdim=32, ssm_chunk=16
    )
