"""internvl2-26b [vlm] — InternViT (stub frontend) + InternLM2 language
decoder backbone [arXiv:2404.16821].

input_specs provides precomputed patch embeddings (the ViT + projector are
the assignment's allowed stub); this config is the 48-layer language decoder
with early fusion.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    arch_type="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    n_patches=256,  # one 448x448 tile -> 256 visual tokens after projector
    source="arXiv:2404.16821 (InternVL 1.5/2 family; InternLM2-20B decoder)",
)


def smoke():
    return CONFIG.replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=1, d_ff=512, vocab=512, n_patches=8
    )
