"""The paper's own primary target/draft pair: Llama-3 70B / 8B Instruct
[arXiv:2407.21783].  TARGET is the assigned-pool-independent "paper config";
DRAFT is the 8B draft.  Used by the paper-faithful benchmarks at full scale
(dry-run only) and, in reduced form, by the runnable experiments."""
from repro.models.config import ModelConfig

TARGET = ModelConfig(
    name="llama3-70b",
    arch_type="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    rope_theta=500000.0,
    source="arXiv:2407.21783 (Llama 3 herd)",
)

DRAFT = ModelConfig(
    name="llama3-8b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    rope_theta=500000.0,
    source="arXiv:2407.21783 (Llama 3 herd)",
)

CONFIG = TARGET


def smoke():
    return TARGET.replace(n_layers=2, d_model=256, n_heads=4, n_kv_heads=1, d_ff=512, vocab=512)


def smoke_draft():
    return DRAFT.replace(n_layers=1, d_model=128, n_heads=2, n_kv_heads=1, d_ff=256, vocab=512)
