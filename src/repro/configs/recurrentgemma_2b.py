"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 pattern
[arXiv:2402.19427]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,  # local MQA
    d_ff=7680,
    vocab=256000,
    hybrid_attn_every=3,  # (rec, rec, local-attn) groups
    lru_width=2560,
    local_window=2048,
    tie_embeddings=True,
    source="arXiv:2402.19427 (Griffin/RecurrentGemma)",
)


def smoke():
    return CONFIG.replace(
        n_layers=5, d_model=256, n_heads=4, n_kv_heads=1, d_ff=512, vocab=512,
        lru_width=256, local_window=64,
    )
