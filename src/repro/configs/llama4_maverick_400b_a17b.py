"""llama4-maverick-400b-a17b [moe] — 128 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E family]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,  # per-expert FFN width
    vocab=202048,
    n_experts=128,
    top_k=1,
    head_dim=128,
    moe_every=2,  # Maverick interleaves dense and MoE layers (1:1)
    moe_dense_ff=16384,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (Llama 4 MoE family)",
)


def smoke():
    return CONFIG.replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=1, d_ff=256, vocab=512,
        n_experts=4, top_k=1, head_dim=64, moe_dense_ff=512,
    )
