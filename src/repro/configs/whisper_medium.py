"""whisper-medium [audio] — enc-dec transformer backbone; conv/mel frontend
is a stub (input_specs provides precomputed frame embeddings)
[arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="encdec",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    enc_len=1500,
    source="arXiv:2212.04356 (Robust Speech Recognition via Large-Scale Weak Supervision)",
)


def smoke():
    return CONFIG.replace(
        n_layers=2, n_enc_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
        vocab=512, enc_len=24,
    )
