"""qwen2-72b [dense] — GQA with QKV bias [arXiv:2407.10671]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    arch_type="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    source="arXiv:2407.10671 (Qwen2 Technical Report)",
)


def smoke():
    return CONFIG.replace(n_layers=2, d_model=256, n_heads=4, n_kv_heads=1, d_ff=512, vocab=512)
