"""Train the neural delay-and-branch selector (NDE, Sec. 6) against a real
model pair and deploy it in the engine.

    PYTHONPATH=src python examples/train_selector.py --roots 16 --steps 150

Flow: offline trace collection (Eq. 3 block-efficiency labels per action +
Eq. 11 latency) -> Eq. 12 training -> engine A/B: static vs NDE policy.
"""
import argparse

import numpy as np

from repro.core.delayed import LatencyModel
from repro.core.selector import FixedSpace, SelectorConfig
from repro.models.config import ModelConfig
from repro.serving.engine import EngineConfig, SamplingParams, SpeculativeEngine
from repro.serving.nde import NeuralSelector
from repro.training.data import SyntheticLM
from repro.training.loop import train
from repro.training.selector_train import best_static_action, collect_traces, train_selector

V = 128
ACTIONS = [(1, 3, 0), (2, 1, 1), (2, 2, 2), (4, 1, 1)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--roots", type=int, default=12, help="labelled roots (per prompt)")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--eval-tokens", type=int, default=48)
    args = ap.parse_args(argv)

    tc = ModelConfig(name="t", n_layers=3, d_model=128, n_heads=4, n_kv_heads=2,
                     d_ff=256, vocab=V, dtype="float32")
    dc = ModelConfig(name="d", n_layers=1, d_model=64, n_heads=2, n_kv_heads=1,
                     d_ff=128, vocab=V, dtype="float32")
    lm = SyntheticLM(V, seed=5)
    tp, _ = train(tc, lm.batches(8, 48, seed=1), steps=80, lr=2e-3, log_every=80)
    dp, _ = train(dc, lm.batches(8, 48, seed=2), steps=80, lr=3e-3, log_every=80)

    lat = LatencyModel(1e-4, 1e-8, 1.2e-3, 1e-7)  # ~12:1 target:draft pass time
    sampling = SamplingParams(0.9, 1.0)
    eng = SpeculativeEngine(tc, tp, dc, dp,
                            EngineConfig(verifier="specinfer", K=2, L1=2, L2=2, max_cache=512),
                            sampling)

    print("[1/3] collecting offline traces (Eq. 3 labels per action)")
    rng = np.random.default_rng(0)
    prompts = [lm.sample(rng, 8).tolist() for _ in range(3)]
    traces = collect_traces(eng, prompts, ACTIONS, lat,
                            tokens_per_prompt=args.roots, stride=6, s=1)
    print(f"  {traces['eff'].shape[0]} roots x {len(ACTIONS)} actions labelled")

    print("[2/3] training the selector (Eq. 12)")
    scfg = SelectorConfig(hidden_p=tc.d_model, hidden_q=dc.d_model, space=FixedSpace(ACTIONS))
    sel_params, losses = train_selector(traces, scfg, steps=args.steps, batch=16, lam=0.3)
    print(f"  loss {losses[0]:.4f} -> {losses[-1]:.4f}")

    print("[3/3] A/B: static best action vs NDE policy")
    b = best_static_action(traces)
    Kb, L1b, L2b = ACTIONS[b]
    results = {}
    for name, selector, ecfg in [
        ("static", None, EngineConfig(verifier="specinfer", K=Kb, L1=L1b, L2=L2b, max_cache=512)),
        ("nde", NeuralSelector(sel_params, scfg, lat, sampling),
         EngineConfig(verifier="specinfer", max_cache=512)),
    ]:
        e = SpeculativeEngine(tc, tp, dc, dp, ecfg, sampling, selector=selector)
        e.rng = np.random.default_rng(1)
        tot_time = 0.0
        produced = 0
        stream = e.new_stream(lm.sample(np.random.default_rng(2), 8).tolist())
        while produced < args.eval_tokens:
            K, L1, L2 = e.choose_action(stream)
            tot_time += lat.action_time(len(stream["committed"]), K, L1, L2)
            produced += len(e.step(stream))
        results[name] = produced / tot_time
        be = e.counters["accepted"] / e.counters["blocks"] + 1
        print(f"  {name:7s} modelled TPS={results[name]:8.2f}  block_eff={be:.2f}")
    print(f"\nNDE/static throughput ratio: {results['nde'] / results['static']:.3f}")


if __name__ == "__main__":
    main()
