"""Quickstart: lossless multi-path speculative decoding in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a tiny target + draft pair, drafts (K, L1, L2)-delayed trees, verifies
with SpecInfer and with Traversal, and shows the block-efficiency difference.
"""
import jax

from repro.models.config import ModelConfig
from repro.models.transformer import init_params
from repro.serving.engine import EngineConfig, SamplingParams, SpeculativeEngine

VOCAB = 128
target_cfg = ModelConfig(name="target", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                         d_ff=256, vocab=VOCAB, dtype="float32")
draft_cfg = ModelConfig(name="draft", n_layers=1, d_model=64, n_heads=2, n_kv_heads=2,
                        d_ff=128, vocab=VOCAB, dtype="float32")

target_params = init_params(target_cfg, jax.random.PRNGKey(0))
draft_params = init_params(draft_cfg, jax.random.PRNGKey(1))

prompt = [7, 3, 11, 42]
for verifier in ["specinfer", "traversal"]:
    engine = SpeculativeEngine(
        target_cfg, target_params, draft_cfg, draft_params,
        EngineConfig(verifier=verifier, K=2, L1=2, L2=2, max_cache=256, seed=0),
        SamplingParams(temperature=0.8, top_p=0.95),
    )
    out = engine.generate(prompt, max_new=40)
    c = engine.counters
    be = c["accepted"] / c["blocks"] + 1
    print(f"{verifier:10s} -> {out[:12]}...  block_efficiency={be:.2f} "
          f"(target calls: {c['target_calls']}, tokens: {len(out)})")

print("\nBoth outputs are exact samples from the target distribution —")
print("see tests/test_lossless.py for the enumeration proof of every verifier.")
