"""Compare every registered verification algorithm on one model pair
(Sec. 4 in miniature) — same drafts, same sampling, matched settings.
The list is the core/verify.py registry itself, so newly registered
verifiers show up here automatically (single-path ones at K = 1, on a
matched 4-node budget).

    PYTHONPATH=src python examples/compare_verifiers.py --max-new 32
"""
import argparse

import numpy as np

from repro.core.verify import VERIFIERS as REGISTRY
from repro.models.config import ModelConfig
from repro.serving.engine import EngineConfig, SamplingParams, SpeculativeEngine
from repro.training.data import SyntheticLM
from repro.training.loop import train

V = 128
VERIFIERS = [
    (name, *((1, 0, 4) if not spec.multipath else (2, 0, 2)))
    for name, spec in sorted(REGISTRY.items())
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--train-steps", type=int, default=60)
    args = ap.parse_args(argv)

    tc = ModelConfig(name="t", n_layers=3, d_model=128, n_heads=4, n_kv_heads=2,
                     d_ff=256, vocab=V, dtype="float32")
    dc = ModelConfig(name="d", n_layers=1, d_model=64, n_heads=2, n_kv_heads=1,
                     d_ff=128, vocab=V, dtype="float32")
    lm = SyntheticLM(V, seed=9)
    tp, _ = train(tc, lm.batches(8, 48, seed=1), steps=args.train_steps, lr=2e-3, log_every=999)
    dp, _ = train(dc, lm.batches(8, 48, seed=2), steps=args.train_steps, lr=3e-3, log_every=999)

    rng = np.random.default_rng(0)
    prompt = lm.sample(rng, 10).tolist()
    print(f"{'verifier':14s} {'(K,L1,L2)':>10s} {'block_eff':>10s} {'target_calls':>13s}")
    for verifier, K, L1, L2 in VERIFIERS:
        eng = SpeculativeEngine(
            tc, tp, dc, dp,
            EngineConfig(verifier=verifier, K=K, L1=L1, L2=L2, max_cache=512, seed=3),
            SamplingParams(args.temperature, 1.0),
        )
        eng.generate(list(prompt), max_new=args.max_new)
        c = eng.counters
        be = c["accepted"] / c["blocks"] + 1
        print(f"{verifier:14s} {f'({K},{L1},{L2})':>10s} {be:10.3f} {c['target_calls']:13d}")


if __name__ == "__main__":
    main()
