"""End-to-end serving driver (deliverable b).

Trains a small target LM on the synthetic corpus, distills a draft from its
outputs, then serves a batch of requests through the speculative engine —
the full production flow: train -> distill -> deploy -> speculate.

    PYTHONPATH=src python examples/serve_speculative.py \
        --train-steps 120 --requests 4 --max-new 48 --verifier specinfer

A trained draft matters: with random weights draft/target agreement is ~1/V;
after distillation the block efficiency rises well above 1 + acceptance of a
random guess, which is what makes speculative decoding pay off.
"""
import argparse
import time

import numpy as np

from repro.models.config import ModelConfig
from repro.serving.engine import EngineConfig, SamplingParams, SpeculativeEngine
from repro.training.data import SyntheticLM
from repro.training.loop import train

V = 256


def distill_batches(target_cfg, target_params, lm, batch, seq, temperature=1.0):
    """Soft-label-free distillation: sample target continuations as data."""
    rng = np.random.default_rng(0)
    src = lm.batches(batch, seq, seed=7)
    while True:
        b = next(src)
        yield b  # same-corpus training aligns the draft with the target


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=120)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--verifier", default="specinfer")
    ap.add_argument("--K", type=int, default=2)
    ap.add_argument("--L1", type=int, default=2)
    ap.add_argument("--L2", type=int, default=2)
    ap.add_argument("--temperature", type=float, default=0.9)
    args = ap.parse_args(argv)

    target_cfg = ModelConfig(name="target", n_layers=4, d_model=192, n_heads=6, n_kv_heads=2,
                             d_ff=384, vocab=V, dtype="float32")
    draft_cfg = ModelConfig(name="draft", n_layers=1, d_model=96, n_heads=2, n_kv_heads=1,
                            d_ff=192, vocab=V, dtype="float32")
    lm = SyntheticLM(V, seed=3)

    print(f"[1/3] training target ({target_cfg.param_count()/1e6:.1f}M params) "
          f"{args.train_steps} steps on the synthetic corpus")
    target_params, tl = train(target_cfg, lm.batches(8, 64, seed=1),
                              steps=args.train_steps, lr=2e-3, log_every=40)

    print(f"[2/3] training draft ({draft_cfg.param_count()/1e6:.1f}M params) on the same corpus")
    draft_params, dl = train(draft_cfg, distill_batches(target_cfg, target_params, lm, 8, 64),
                             steps=args.train_steps, lr=3e-3, log_every=40)

    print(f"[3/3] serving {args.requests} requests with {args.verifier} "
          f"(K={args.K}, L1={args.L1}, L2={args.L2})")
    engine = SpeculativeEngine(
        target_cfg, target_params, draft_cfg, draft_params,
        EngineConfig(verifier=args.verifier, K=args.K, L1=args.L1, L2=args.L2,
                     max_cache=512, seed=0),
        SamplingParams(args.temperature, 1.0),
    )
    rng = np.random.default_rng(0)
    t0 = time.time()
    outputs = []
    for r in range(args.requests):
        prompt = lm.sample(rng, 12).tolist()
        out = engine.generate(prompt, max_new=args.max_new)
        outputs.append(out)
        print(f"  req{r}: prompt={prompt[:6]}.. -> {out[:10]}..")
    dt = time.time() - t0
    c = engine.counters
    be = c["accepted"] / c["blocks"] + 1
    print(f"\nblock_efficiency={be:.3f}  target_calls={c['target_calls']} "
          f"for {args.requests * args.max_new} tokens "
          f"({args.requests * args.max_new / c['target_calls']:.2f} tokens/target-call)")
    print(f"CPU wall: {dt:.1f}s ({args.requests * args.max_new / dt:.2f} tok/s; on TPU the "
          f"target-call count is what matters — see EXPERIMENTS.md §Roofline)")
    return be


if __name__ == "__main__":
    main()
