"""Continuous-batching speculative serving in ~40 lines.

    PYTHONPATH=src python examples/serve_batched.py

Builds a smoke-scale target/draft pair, submits more requests than the pool
has slots, and drains them through ``BatchedSpeculativeEngine``: requests
queue FIFO, join a cache-pool slot when one frees up, and every draft/target
model call advances all resident streams at once.  Per-stream seeds make
each output identical to a dedicated single-stream engine run.
"""
import jax
import numpy as np

from repro.configs import get_smoke
from repro.launch.serve import make_draft_cfg
from repro.models.transformer import init_params
from repro.serving.batch_engine import BatchedSpeculativeEngine
from repro.serving.engine import EngineConfig, SamplingParams


def main():
    cfg = get_smoke("granite-8b")
    dcfg = make_draft_cfg(cfg)
    tp = init_params(cfg, jax.random.PRNGKey(0))
    dp = init_params(dcfg, jax.random.PRNGKey(1))

    engine = BatchedSpeculativeEngine(
        cfg, tp, dcfg, dp,
        EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=256),
        SamplingParams(temperature=0.9),
        n_slots=4,  # 4 resident streams; further requests queue
    )

    rng = np.random.default_rng(0)
    rids = [
        engine.submit(rng.integers(0, cfg.vocab, size=6).tolist(), max_new=24, seed=100 + i)
        for i in range(6)
    ]
    outputs = engine.run()
    for i, rid in enumerate(rids):
        print(f"request {i}: {outputs[rid]['tokens'][:12]}...")

    c = engine.counters
    print(
        f"\n{len(rids)} requests, {c['blocks']} speculative blocks in "
        f"{c['target_calls']} batched target calls "
        f"(block efficiency {c['accepted'] / max(c['blocks'], 1) + 1:.2f})"
    )


if __name__ == "__main__":
    main()
