"""Training substrate tests: optimizer behaviour, checkpoint roundtrip, data
pipeline determinism, selector training objective."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.delayed import LatencyModel
from repro.core.selector import FixedSpace, SelectorConfig, init_selector, selector_loss
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.data import SyntheticLM
from repro.training.optim import AdamW


def test_adamw_minimises_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    st = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, st = opt.update(g, st, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_grad_clipping():
    opt = AdamW(lr=1e-3, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    st = opt.init(params)
    g = {"w": jnp.asarray([1e6, 1e6, 1e6])}
    p2, _ = opt.update(g, st, params)
    assert np.isfinite(np.asarray(p2["w"])).all()


def test_cosine_schedule_monotone_tail():
    opt = AdamW(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(opt.schedule(jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9]  # warmup
    assert lrs[99] < lrs[50] < lrs[11]  # cosine decay


def test_checkpoint_roundtrip_bf16():
    params = {
        "a": jnp.asarray(np.random.randn(4, 4), jnp.bfloat16),
        "nested": {"b": jnp.arange(5, dtype=jnp.float32)},
        "stack": jnp.ones((2, 3), jnp.int32),
    }
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ck.npz")
        save_checkpoint(path, params, step=7)
        p2, step = load_checkpoint(path, template=params)
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_synthetic_lm_determinism_and_learnability():
    lm = SyntheticLM(64, seed=1)
    b1 = next(lm.batches(2, 16, seed=5))
    b2 = next(lm.batches(2, 16, seed=5))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # the structure is CONDITIONAL: given the hidden 2nd-order state, the
    # next token is drawn from <= branch candidates (so conditional entropy
    # <= log(branch) << log(vocab)), which is what a model can learn
    rng = np.random.default_rng(0)
    toks = lm.sample(rng, 4000)
    support = {}
    for i in range(2, len(toks)):
        s = lm._state(int(toks[i - 2]), int(toks[i - 1]))
        support.setdefault(s, set()).add(int(toks[i]))
    max_support = max(len(v) for v in support.values())
    assert max_support <= lm.branch
    # mean table-row entropy is far below uniform over the vocab
    row_H = -(lm.weights * np.log(np.clip(lm.weights, 1e-12, None))).sum(axis=1).mean()
    assert row_H < np.log(64) * 0.6


@pytest.mark.slow
def test_selector_loss_prefers_better_actions():
    """After training on a batch where action 1 dominates, the policy must
    put its argmax on action 1."""
    space = FixedSpace([(1, 1, 0), (2, 1, 1), (2, 2, 2)])
    scfg = SelectorConfig(hidden_p=8, hidden_q=8, space=space, dropout=0.0)
    params = init_selector(scfg, jax.random.PRNGKey(0))
    B = 16
    batch = {
        "h_prev_p": jnp.ones((B, 8)),
        "h_prev_q": jnp.ones((B, 8)),
        "h_cur_q": jnp.ones((B, 8)),
        "scalars": jnp.ones((B, 11)),
        "eff": jnp.tile(jnp.asarray([[1.0, 4.0, 1.5]]), (B, 1)),
        "time": jnp.ones((B, 3)),
        "base": jnp.zeros((B,), jnp.int32),
    }
    opt = AdamW(lr=3e-3)
    st = opt.init(params)
    for _ in range(150):
        g = jax.grad(lambda p: selector_loss(p, batch))(params)
        params, st = opt.update(g, st, params)
    from repro.core.selector import selector_logits

    logits = selector_logits(params, batch["h_prev_p"], batch["h_prev_q"],
                             batch["h_cur_q"], batch["scalars"])
    assert int(jnp.argmax(logits[0])) == 1


def test_latency_model_eq11():
    lat = LatencyModel(t_q_base=1.0, t_q_per_tok=0.1, t_p_base=10.0, t_p_per_tok=0.0)
    # Eq. 11: trunk L1=2 at ctx 5: t_q(5)+t_q(6); branch L2=2, K=3:
    # t_q(7)+t_q(7+3); target at 5+2+6=13
    t = lat.action_time(5, 3, 2, 2)
    expect = (1.5 + 1.6) + (1.7 + 2.0) + 10.0
    assert abs(t - expect) < 1e-9
