"""Counter accuracy for the bench surface.

The 9 -> 17 ``commit_calls`` regression on the sharded bench row shipped
silently because nothing tested the counters themselves — the bench gates
compare counter values, so a counter that drifts from the work it claims
to measure silently re-opens the regression it gates.  These tests pin
each reported counter to ground truth from an instrumented run:

  * ``commit_calls`` == the number of commit dispatches that actually
    reached the jit cache (single-engine ``commit_T*`` keys, engine-level
    ``gcommit_*`` keys for the grouped cross-shard commit);
  * the grouped commit really regroups: 2-shard ``commit_calls`` stays
    within ``single-shard + shards`` (the bench_smoke.sh gate, at unit
    scale);
  * ``commit_ms`` is a plausible wall fraction under ``profile_commits``;
  * per-shard ``blocks_peak`` (the bench's ``shard_blocks_peak`` column)
    equals the observed per-shard used-block maximum;
  * ``pipeline_iterations`` == steps actually taken, and the overlap
    invariant ``pipeline_ahead + pipeline_stalls == pipeline_iterations``
    holds on the numbers benchmarks/batch_throughput.py reports.
"""
import pathlib
import sys
import time

import jax
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
import benchmarks.batch_throughput as bt

from repro.models.config import ModelConfig
from repro.models.transformer import init_params
from repro.serving.batch_engine import (
    BatchedSpeculativeEngine,
    ShardedBatchedSpeculativeEngine,
)
from repro.serving.engine import EngineConfig

V = 32

DENSE_T = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")
DENSE_D = ModelConfig(name="d", arch_type="dense", n_layers=1, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")

PROMPTS = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [3, 1]]
SEEDS = [20, 21, 22, 23]


@pytest.fixture(scope="module")
def dense_models():
    return (DENSE_T, init_params(DENSE_T, jax.random.PRNGKey(0)),
            DENSE_D, init_params(DENSE_D, jax.random.PRNGKey(1)))


def _count_commit_jits(obj, tally, prefixes):
    """Wrap ``obj._jit`` so every invocation of a commit-dispatch callable
    increments ``tally`` — ground truth independent of the counters."""
    orig = obj._jit

    def counting(name, fn, donate_argnums=None):
        f = orig(name, fn, donate_argnums)
        if name.startswith(prefixes):
            def wrapped(*a, **kw):
                tally[0] += 1
                return f(*a, **kw)
            return wrapped
        return f

    obj._jit = counting


class _CountingSingle(BatchedSpeculativeEngine):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.true_commits = [0]
        self.true_steps = 0
        _count_commit_jits(self, self.true_commits, ("commit_T",))

    def step(self):
        self.true_steps += 1
        return super().step()


class _CountingSharded(ShardedBatchedSpeculativeEngine):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.true_commits = [0]
        self.true_blocks_peak = [0] * self.data_shards
        _count_commit_jits(self, self.true_commits, ("gcommit_",))
        for si, sh in enumerate(self.shards):
            _count_commit_jits(sh, self.true_commits, ("commit_T",))
            self._track_peak(si, sh)

    def _track_peak(self, si, sh):
        begin0, outer = sh.begin_step, self

        def begin(*a, **kw):
            pending = begin0(*a, **kw)
            # sample at the point of maximum mapping: speculative blocks
            # are live right after the dispatch, before commit trims them
            if hasattr(sh.tpool, "used_blocks"):  # paged arenas only
                outer.true_blocks_peak[si] = max(outer.true_blocks_peak[si],
                                                 sh.tpool.used_blocks)
            return pending
        sh.begin_step = begin


def test_single_engine_commit_counters(dense_models):
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    eng = _CountingSingle(tc, tp, dc, dp, ecfg, n_slots=4)
    eng.profile_commits = True
    t0 = time.perf_counter()
    eng.generate_batch(PROMPTS, max_new=10, seeds=SEEDS)
    wall_ms = (time.perf_counter() - t0) * 1e3
    assert eng.counters["commit_calls"] == eng.true_commits[0] > 0
    assert 0 < eng.counters["commit_ms"] <= wall_ms


def test_sharded_commit_counters_and_grouping(dense_models):
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    single = _CountingSingle(tc, tp, dc, dp, ecfg, n_slots=4)
    want = single.generate_batch(PROMPTS, max_new=10, seeds=SEEDS)
    eng = _CountingSharded(tc, tp, dc, dp, ecfg, n_slots=4, data_shards=2)
    eng.profile_commits = True
    assert eng.generate_batch(PROMPTS, max_new=10, seeds=SEEDS) == want
    # the summed counter equals the dispatches that actually happened...
    assert eng.counters["commit_calls"] == eng.true_commits[0] > 0
    # ...the grouped path really fired (engine-level, belongs to no shard)...
    assert eng._counters["commit_calls"] > 0
    assert eng.counters["commit_ms"] > 0
    # ...and regrouping holds the bench gate at unit scale: sharding may
    # add at most one straggler dispatch per shard over the single engine
    assert eng.counters["commit_calls"] <= \
        single.counters["commit_calls"] + eng.data_shards


def test_bench_surface_sharded_counters(dense_models, monkeypatch):
    """prepare_batched must report counters that match the instrumented
    engine underneath it — per-shard block peaks included."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    monkeypatch.setattr(bt, "ShardedBatchedSpeculativeEngine", _CountingSharded)
    eng, workload, commit_stats, occ, warm = bt.prepare_batched(
        tc, tp, dc, dp, ecfg, None, PROMPTS, 10, SEEDS, data_shards=2)
    assert commit_stats["commit_calls"] == eng.true_commits[0] > 0
    assert commit_stats["commit_ms"] > 0
    assert commit_stats["shard_blocks_peak"] == eng.true_blocks_peak
    assert occ and occ["target"]["blocks_used"] > 0
    # the compile-hygiene surface: the warmup pass compiled something, and
    # the census sums every shard's cache (>= the grouped-commit entry alone)
    assert warm["compile_count"] == eng.jit_compile_count() > 0
    assert warm["warmup_secs"] > 0
    # the timed-pass counters start from zero, not the warmup's tallies
    assert eng.counters["commit_calls"] == 0


def test_bench_surface_overlap_invariant(dense_models, monkeypatch):
    """The overlap counters the bench prints describe one workload pass:
    iterations == steps actually taken, ahead + stalls == iterations."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    monkeypatch.setattr(bt, "BatchedSpeculativeEngine", _CountingSingle)
    eng, workload, _, _, _ = bt.prepare_batched(
        tc, tp, dc, dp, ecfg, None, PROMPTS, 10, SEEDS, pipeline=True)
    eng.true_steps = 0
    workload()
    c = eng.counters
    assert c["pipeline_iterations"] == eng.true_steps > 0
    assert c["pipeline_ahead"] + c["pipeline_stalls"] == c["pipeline_iterations"]