"""Tiny vendored property-check shim: a hypothesis-free `given/settings/
strategies` workalike driven by `np.random.default_rng`.

The environment has no `hypothesis`, but the losslessness suites are
property tests at heart.  This shim keeps their shape — strategies describe
the case space, `@given` sweeps it — with deterministic seeding (crc32 of
the test name), so runs are reproducible and the same case diversity is
preserved.  No shrinking; on failure the drawn example is attached to the
assertion so the case can be replayed by hand.

Usage (drop-in for the subset the suites use):

    from _propcheck import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.floats(0.1, 0.999))
    def test_prop(seed, top_p): ...
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)


class strategies:
    """Namespace mirroring `hypothesis.strategies` (the used subset)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        # hypothesis bounds are inclusive
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: float(min_value + (max_value - min_value) * rng.random()))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(2)))


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_ignored):
    """Set the sweep size.  Composes with @given in either order."""

    def deco(fn):
        fn._propcheck_max_examples = max_examples
        return fn

    return deco


def given(*strats: _Strategy):
    """Sweep the wrapped test over `max_examples` deterministic draws."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_propcheck_max_examples", None)
            if n is None:
                n = getattr(fn, "_propcheck_max_examples", _DEFAULT_EXAMPLES)
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                example = tuple(s.example(rng) for s in strats)
                try:
                    fn(*args, *example, **kwargs)
                except AssertionError as e:
                    raise AssertionError(
                        f"falsifying example #{i}: {fn.__name__}{example}: {e}"
                    ) from e

        # the strategy-bound params are filled by the sweep, not by pytest
        # fixtures — present a parameterless signature to collection
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco
