"""The core/verify.py verifier registry contract.

The registry is the single dispatch surface both engines and the bench
harnesses resolve verification through, so its failure modes must be loud:
unknown names fail at build time with the registered list attached,
duplicate registration is an error, and every registered name round-trips
through the serving CLI (launch/serve.py --verifier).
"""
import numpy as np
import pytest

from repro.core.enumerate import RandomModel, iter_trees
from repro.core.verify import (
    VERIFIERS,
    Verifier,
    VerifierSpec,
    get_verifier,
    register_verifier,
    verifier_names,
)

EXPECTED = {"bv", "greedy_mpbv", "khisti", "naive", "naive_single", "naivetree",
            "nss", "specinfer", "spectr", "traversal", "univer"}


def test_registry_contents():
    assert set(verifier_names()) == EXPECTED
    # exactly the single-path verifiers are flagged K=1-only, and exactly
    # the OT top-down family has the batched on-device solve
    assert {n for n in EXPECTED if not VERIFIERS[n].multipath} == {"bv", "naive_single"}
    assert {n for n in EXPECTED if VERIFIERS[n].on_device} == \
        {"khisti", "naive", "naivetree", "nss", "specinfer", "spectr"}


def test_specs_satisfy_protocol():
    for name in verifier_names():
        spec = get_verifier(name)
        assert isinstance(spec, Verifier)
        assert spec.name == name
        assert spec.cite  # every verifier names its source


def test_unknown_name_fails_loudly():
    with pytest.raises(ValueError, match="unknown verifier 'nope'"):
        get_verifier("nope")
    # the error carries the registered names so the caller can self-serve
    with pytest.raises(ValueError, match="specinfer"):
        get_verifier("nope")


def test_duplicate_registration_rejected():
    spec = get_verifier("specinfer")
    with pytest.raises(ValueError, match="already registered"):
        register_verifier(VerifierSpec(name="specinfer", _verify=spec._verify,
                                       _output_dist=spec._output_dist))
    assert get_verifier("specinfer") is spec  # the original survived


def test_serve_cli_roundtrip():
    """launch/serve.py --verifier accepts every registered name and nothing
    else — the CLI choices are derived from the registry, not a hand list."""
    from repro.launch.serve import build_parser

    for name in verifier_names():
        args = build_parser().parse_args(["--arch", "granite-8b", "--verifier", name])
        assert args.verifier == name
    with pytest.raises(SystemExit):
        build_parser().parse_args(["--arch", "granite-8b", "--verifier", "nope"])


def test_engine_rejects_unknown_verifier_at_build_time():
    from repro.serving.engine import EngineConfig, SpeculativeEngine

    ecfg = EngineConfig(verifier="nope")
    with pytest.raises(ValueError, match="unknown verifier"):
        # params are never touched: validation precedes any model work
        SpeculativeEngine(_FakeCfg(), None, _FakeCfg(), None, ecfg)


class _FakeCfg:
    vocab = 3
    arch_type = "dense"


def test_sampled_block_lies_in_output_dist_support():
    """verify() and output_dist() describe the same law: any sampled
    (accepted + correction) block must be a support point of the exact
    conditional block distribution, for every registered verifier."""
    model = RandomModel(3, seed=3, divergence=0.8)
    for name in verifier_names():
        spec = VERIFIERS[name]
        K = 2 if spec.multipath else 1
        rng = np.random.default_rng(7)
        tree, _ = next(iter_trees(model, K, 1, 1))
        d = spec.output_dist(tree)
        assert abs(sum(d.values()) - 1.0) < 1e-9, name
        for trial in range(20):
            accepted, corr = spec.verify(tree, rng)
            blk = tuple(accepted) + (corr,)
            assert blk in d and d[blk] > 0, \
                f"{name}: sampled block {blk} has zero mass in output_dist"
