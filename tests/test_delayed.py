"""Delayed-expansion machinery: Eq. 3 estimator correctness, acceptance-depth
analysis (Fig. 1 signal), and the sampling distributions."""
import numpy as np
import pytest

from repro.core.delayed import (
    acceptance_by_depth,
    estimate_block_efficiency,
    expected_block_efficiency,
    l1_by_depth,
)
from repro.core.enumerate import (
    RandomModel,
    iter_trees,
    mean_block_len,
)
from repro.core.trees import attach_target, build_delayed_tree, tree_ancestor_mask
from repro.core.verify import verify_topdown_output_dist


@pytest.mark.parametrize("solver", ["specinfer", "spectr", "naivetree"])
@pytest.mark.parametrize("K,L1,L2", [(2, 1, 1), (2, 0, 2)])
def test_eq3_estimator_matches_exact_block_length(solver, K, L1, L2):
    """Eq. 3 (reach-probability sum) == expected emitted block length from the
    exact conditional output distribution, tree by tree."""
    model = RandomModel(3, seed=21, divergence=0.6)
    for tree, prob in list(iter_trees(model, K, L1, L2))[:20]:
        eq3 = expected_block_efficiency(tree, solver)
        exact = mean_block_len(verify_topdown_output_dist(tree, solver))
        assert abs(eq3 - exact) < 1e-10


def test_eq3_outer_estimator_unbiasedness():
    model = RandomModel(3, seed=2, divergence=0.5)
    rng = np.random.default_rng(0)
    # exact outer expectation
    exact = 0.0
    for tree, prob in iter_trees(model, 2, 1, 1):
        exact += prob * expected_block_efficiency(tree, "specinfer")
    est = np.mean([
        estimate_block_efficiency(np.random.default_rng(s), model.q, model.p,
                                  "specinfer", 2, 1, 1, s=1)
        for s in range(500)
    ])
    assert abs(est - exact) < 0.12, (est, exact)  # ~2.5 sigma of the MC error


def test_delayed_tree_structure():
    model = RandomModel(5, seed=3)
    rng = np.random.default_rng(1)
    tree = build_delayed_tree(rng, model.q, K=3, L1=2, L2=2)
    assert tree.n_nodes == 1 + 2 + 3 * 2
    assert tree.max_depth() == 4
    # trunk is a path; branch node has 3 children
    assert len(tree.children(0)) == 1
    trunk_end = 2
    assert len(tree.children(trunk_end)) == 3
    anc = tree_ancestor_mask(tree.parent)
    assert anc[0, 0] and anc.sum(1).max() == 5  # leaf has 5 ancestors incl self


def test_acceptance_decreases_with_divergence():
    """Def. 5.1 sanity: higher draft-target divergence -> lower acceptance."""
    m_close = RandomModel(6, seed=4, divergence=0.1)
    m_far = RandomModel(6, seed=4, divergence=0.9)
    rng = np.random.default_rng(2)
    accs = {}
    for name, m in [("close", m_close), ("far", m_far)]:
        tree = build_delayed_tree(rng, m.q, K=2, L1=1, L2=1)
        attach_target(tree, m.p)
        vals = [a for _, a in acceptance_by_depth(tree, "specinfer", 2)]
        accs[name] = np.mean(vals)
    assert accs["close"] > accs["far"]


def test_l1_by_depth_shape():
    model = RandomModel(4, seed=6)
    rng = np.random.default_rng(3)
    tree = attach_target(build_delayed_tree(rng, model.q, 2, 1, 2), model.p)
    rows = l1_by_depth(tree)
    assert len(rows) == tree.n_nodes
    assert all(0 <= d <= 3 and 0 <= v <= 2 + 1e-12 for d, v in rows)
