"""Unit tests for OTLP solvers (App. B/C/D).

For every solver:
  * OTLP property (losslessness at a single node): the expectation of the
    exact conditional output distribution over i.i.d. draft draws equals p.
  * acceptance formula (App. C) == acceptance computed from output dists.
  * branching probabilities == output_dist at draft tokens.
  * the sampling implementation agrees with output_dist (Monte Carlo).
"""
import itertools

import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.otlp import OTLP_SOLVERS, acceptance_rate, branching_probs

SOLVERS = ["nss", "naive", "spectr", "specinfer", "khisti"]


def random_pq(rng, V, zeros=False):
    p = rng.dirichlet(np.ones(V))
    q = rng.dirichlet(np.ones(V))
    if zeros:
        p[rng.integers(V)] = 0
        q[rng.integers(V)] = 0
        p /= p.sum()
        q /= q.sum()
    return p, q


def exact_expectation(solver, p, q, k):
    """E_{xs ~ q^k}[output_dist(p, q, xs)] by enumeration."""
    _, output_dist, _ = OTLP_SOLVERS[solver]
    V = len(p)
    out = np.zeros(V)
    for xs in itertools.product(range(V), repeat=k):
        w = np.prod([q[x] for x in xs])
        if w > 0:
            out += w * output_dist(p, q, list(xs))
    return out


@pytest.mark.parametrize("solver", SOLVERS)
@pytest.mark.parametrize("k", [1, 2, 3])
@pytest.mark.parametrize("zeros", [False, True])
def test_otlp_property(solver, k, zeros):
    rng = np.random.default_rng(hash((solver, k, zeros)) % 2**32)
    for _ in range(3):
        p, q = random_pq(rng, 4, zeros)
        np.testing.assert_allclose(exact_expectation(solver, p, q, k), p, atol=1e-10)


@pytest.mark.parametrize("solver", SOLVERS)
@pytest.mark.parametrize("k", [1, 2, 3])
def test_acceptance_formula(solver, k):
    rng = np.random.default_rng(hash((solver, k)) % 2**32)
    _, output_dist, _ = OTLP_SOLVERS[solver]
    for _ in range(3):
        p, q = random_pq(rng, 4)
        # acceptance from exact output dists
        acc = 0.0
        for xs in itertools.product(range(4), repeat=k):
            w = np.prod([q[x] for x in xs])
            if w > 0:
                d = output_dist(p, q, list(xs))
                acc += w * sum(d[x] for x in set(xs))
        formula = acceptance_rate(solver, p, q, k)
        if solver == "khisti":
            assert abs(formula - acc) < 0.08  # Monte-Carlo outer expectation
        else:
            np.testing.assert_allclose(formula, acc, atol=1e-9)


@pytest.mark.parametrize("solver", SOLVERS)
def test_branching_is_output_dist_at_drafts(solver):
    rng = np.random.default_rng(0)
    p, q = random_pq(rng, 5)
    xs = [0, 2, 2]
    _, output_dist, _ = OTLP_SOLVERS[solver]
    d = output_dist(p, q, xs)
    b = branching_probs(solver, p, q, xs)
    np.testing.assert_allclose(b, [d[0], d[2], d[2]], atol=1e-12)


@pytest.mark.parametrize("solver", SOLVERS)
def test_sampler_matches_output_dist(solver):
    rng = np.random.default_rng(1)
    p, q = random_pq(rng, 4)
    xs = [1, 3]
    solve, output_dist, _ = OTLP_SOLVERS[solver]
    d = output_dist(p, q, xs)
    n = 6000
    counts = np.zeros(4)
    for _ in range(n):
        counts[solve(p, q, xs, rng)] += 1
    np.testing.assert_allclose(counts / n, d, atol=0.035)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(2, 6),
    st.integers(1, 3),
    st.integers(0, 2**31 - 1),
    st.sampled_from(SOLVERS),
)
def test_otlp_property_hypothesis(V, k, seed, solver):
    """Property: any (p, q, k) keeps the OTLP marginal exactly p."""
    rng = np.random.default_rng(seed)
    p, q = random_pq(rng, V)
    np.testing.assert_allclose(exact_expectation(solver, p, q, k), p, atol=1e-9)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_spectr_rho_within_bounds(seed, k):
    from repro.core.otlp import _spectr_rho

    rng = np.random.default_rng(seed)
    p, q = random_pq(rng, 5)
    rho = _spectr_rho(p, q, k)
    assert 1.0 <= rho <= k + 1e-9


def test_khisti_importance_dist_valid():
    from repro.core.otlp import khisti_importance_sample

    rng = np.random.default_rng(2)
    for k in (1, 2, 4):
        p, q = random_pq(rng, 6)
        r = khisti_importance_sample(p, q, k)
        assert abs(r.sum() - 1) < 1e-12
        u = 1 - (1 - q) ** k
        assert np.all(r <= u + 1e-9)
