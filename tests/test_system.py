"""System-level behaviour: the paper's qualitative claims reproduced at toy
scale (these are the EXPERIMENTS.md §claims smoke-level counterparts)."""
import numpy as np

from repro.core.delayed import estimate_block_efficiency
from repro.core.enumerate import RandomModel, expected_block_dist, mean_block_len
from repro.core.traversal import verify_traversal_output_dist
from repro.core.verify import verify_topdown_output_dist


def _avg_block_len(dist_fn, model, K, L1, L2):
    return mean_block_len(expected_block_dist(dist_fn, model, K, L1, L2))


def test_traversal_dominates_root_rollouts():
    """Paper Sec. 4: under i.i.d. ROOT rollouts (L1=0), Traversal beats the
    OT methods on average block efficiency."""
    scores = {"traversal": 0.0, "specinfer": 0.0, "nss": 0.0}
    for seed in range(4):
        model = RandomModel(3, seed=100 + seed, divergence=0.6)
        scores["traversal"] += _avg_block_len(verify_traversal_output_dist, model, 2, 0, 2)
        for s in ("specinfer", "nss"):
            scores[s] += _avg_block_len(
                lambda t, s=s: verify_topdown_output_dist(t, s), model, 2, 0, 2
            )
    assert scores["traversal"] > scores["specinfer"] > scores["nss"]


def test_delayed_expansion_helps_ot_methods():
    """Paper Sec. 5: when draft-target divergence jumps past a depth (the
    Fig. 1 mechanism), moving the branch point to that depth beats root
    branching even with FEWER tree nodes ("wasteful expansion" of shallow
    i.i.d. rollouts)."""
    import zlib

    class DepthDivergingModel(RandomModel):
        def _dists(self, ctx):
            if ctx not in self._cache:
                rng = np.random.default_rng(zlib.crc32(repr(("m", self.seed, ctx)).encode()))
                p = rng.dirichlet(np.ones(self.vocab))
                noise = rng.dirichlet(np.ones(self.vocab))
                w = 0.05 if len(ctx) < 1 else 0.9  # aligned at the root, divergent after
                q = (1 - w) * p + w * noise
                self._cache[ctx] = (p, q)
            return self._cache[ctx]

    gains = 0
    deltas = []
    for seed in range(8):
        model = DepthDivergingModel(3, seed=400 + seed)
        root = _avg_block_len(
            lambda t: verify_topdown_output_dist(t, "specinfer"), model, 3, 0, 2
        )  # 6 nodes, branch at the root
        delayed = _avg_block_len(
            lambda t: verify_topdown_output_dist(t, "specinfer"), model, 3, 1, 1
        )  # 4 nodes, branch where divergence starts
        deltas.append(delayed - root)
        gains += delayed > root
    assert gains >= 6, (gains, deltas)
    assert np.mean(deltas) > 0, deltas


def test_block_efficiency_monotone_in_K():
    """More i.i.d. branches never hurt expected block efficiency."""
    model = RandomModel(3, seed=33, divergence=0.7)
    rng = np.random.default_rng(0)
    effs = [
        estimate_block_efficiency(np.random.default_rng(1), model.q, model.p,
                                  "specinfer", K, 0, 2, s=64)
        for K in (1, 2, 3)
    ]
    assert effs[0] <= effs[1] + 0.05 and effs[1] <= effs[2] + 0.05
