"""Batch-vs-single exactness: the continuous-batching engine's contract.

For identical per-stream seeds and prompts, ``BatchedSpeculativeEngine`` with
N resident streams must emit token-identical output to N independent
``SpeculativeEngine`` runs — across verifiers, across both target-pass
strategies ("tree" for attention archs, "replay" for recurrent archs),
under heterogeneous prompt lengths, selector-driven heterogeneous tree
shapes, and continuous admission (more requests than pool slots).
"""
import jax
import pytest

from repro.models.config import ModelConfig
from repro.models.transformer import init_params
from repro.serving.batch_engine import BatchedSpeculativeEngine
from repro.serving.engine import EngineConfig, SamplingParams, SpeculativeEngine

V = 32

DENSE_T = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")
DENSE_D = ModelConfig(name="d", arch_type="dense", n_layers=1, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")
SSM_CFG = ModelConfig(name="s", arch_type="ssm", n_layers=2, d_model=48, vocab=V,
                      ssm_state=16, ssm_headdim=16, ssm_chunk=8, dtype="float32")
HYB_CFG = ModelConfig(name="h", arch_type="hybrid", n_layers=5, d_model=48, n_heads=4,
                      n_kv_heads=1, d_ff=96, vocab=V, local_window=32, dtype="float32")

PROMPTS = [[1, 2, 3], [4, 5], [6, 7, 8, 9]]
SEEDS = [20, 21, 22]


@pytest.fixture(scope="module")
def dense_models():
    return (DENSE_T, init_params(DENSE_T, jax.random.PRNGKey(0)),
            DENSE_D, init_params(DENSE_D, jax.random.PRNGKey(1)))


def _single_outputs(tc, tp, dc, dp, ecfg, prompts, seeds, max_new, sampling=None, selector=None):
    outs = []
    for p, sd in zip(prompts, seeds):
        eng = SpeculativeEngine(
            tc, tp, dc, dp,
            EngineConfig(verifier=ecfg.verifier, K=ecfg.K, L1=ecfg.L1, L2=ecfg.L2,
                         max_cache=ecfg.max_cache, seed=sd),
            sampling, selector=selector,
        )
        outs.append(eng.generate(list(p), max_new=max_new))
    return outs


@pytest.mark.parametrize("verifier", ["specinfer", "traversal", "univer", "greedy_mpbv"])
def test_batch_matches_single_tree_strategy(dense_models, verifier):
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier=verifier, K=2, L1=1, L2=1, max_cache=128)
    singles = _single_outputs(tc, tp, dc, dp, ecfg, PROMPTS, SEEDS, max_new=16)
    beng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4)
    assert beng.strategy == "tree"
    outs = beng.generate_batch(PROMPTS, max_new=16, seeds=SEEDS)
    assert outs == singles


@pytest.mark.slow
@pytest.mark.parametrize("verifier", ["specinfer", "traversal", "univer", "greedy_mpbv"])
@pytest.mark.parametrize("cfg", [SSM_CFG, HYB_CFG], ids=["ssm", "hybrid"])
def test_batch_matches_single_replay_strategy(cfg, verifier):
    params = init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(verifier=verifier, K=2, L1=1, L2=1, max_cache=128)
    singles = _single_outputs(cfg, params, cfg, params, ecfg, PROMPTS, SEEDS, max_new=10)
    beng = BatchedSpeculativeEngine(cfg, params, cfg, params, ecfg, n_slots=4)
    assert beng.strategy == "replay"
    outs = beng.generate_batch(PROMPTS, max_new=10, seeds=SEEDS)
    assert outs == singles


@pytest.mark.slow
def test_continuous_admission_exact(dense_models):
    """More requests than slots: queued requests join as slots free up, and
    every stream still matches its independent single-engine run."""
    tc, tp, dc, dp = dense_models
    prompts = [[i + 1, i + 2] for i in range(5)]
    # staggered lengths so slots free at different times
    max_news = [6, 14, 10, 8, 12]
    seeds = [30 + i for i in range(5)]
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    singles = [
        _single_outputs(tc, tp, dc, dp, ecfg, [p], [sd], max_new=mn)[0]
        for p, sd, mn in zip(prompts, seeds, max_news)
    ]
    beng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=2)
    rids = [beng.submit(p, max_new=mn, seed=sd)
            for p, sd, mn in zip(prompts, seeds, max_news)]
    outs = beng.run()
    assert [outs[r]["tokens"] for r in rids] == singles
    # the pool is fully drained and reusable; run() handed over every result
    assert beng.tpool.free_slots == 2
    assert beng.dpool.free_slots == 2
    assert not beng.streams and not beng.queue and not beng.finished


@pytest.mark.slow
def test_heterogeneous_selector_actions_exact(dense_models):
    """Per-stream NDE-style selector decisions: tree shapes differ across
    streams in one iteration (exercising the shape buckets), yet outputs
    still match the single-engine runs with the same selector."""
    tc, tp, dc, dp = dense_models

    def selector(stream, engine):
        # deterministic function of stream state, available in both engines
        return (1 + len(stream["committed"]) % 2, len(stream["committed"]) % 2, 1)

    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    singles = _single_outputs(tc, tp, dc, dp, ecfg, PROMPTS, SEEDS, max_new=12,
                              selector=selector)
    beng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, selector=selector, n_slots=4)
    outs = beng.generate_batch(PROMPTS, max_new=12, seeds=SEEDS)
    assert outs == singles


@pytest.mark.slow
def test_sampling_params_exact(dense_models):
    """Temperature/nucleus warping flows through the batched path."""
    tc, tp, dc, dp = dense_models
    sampling = SamplingParams(temperature=0.8, top_p=0.9)
    ecfg = EngineConfig(verifier="traversal", K=2, L1=1, L2=1, max_cache=128)
    singles = _single_outputs(tc, tp, dc, dp, ecfg, PROMPTS, SEEDS, max_new=12,
                              sampling=sampling)
    beng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, sampling, n_slots=4)
    outs = beng.generate_batch(PROMPTS, max_new=12, seeds=SEEDS)
    assert outs == singles


@pytest.mark.slow
def test_eviction_on_cache_pressure(dense_models):
    """A stream whose ring cannot hold another speculation block finishes
    early (evicted) instead of corrupting its cache."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=24)
    beng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=2)
    rid = beng.submit([1, 2, 3], max_new=64, seed=7)
    info = beng.run()[rid]
    assert info["reason"].startswith("evicted")
    assert 0 < len(info["tokens"]) < 64
    assert beng.counters["evicted"] == 1
    # slot was released — the pool accepts new work afterwards, and the second
    # drain only returns the second request
    rid2 = beng.submit([3, 2], max_new=4, seed=8)
    out = beng.run()
    assert list(out) == [rid2]
    assert len(out[rid2]["tokens"]) == 4


def test_pooled_peeks_match_single_engine(dense_models):
    """The pooled peek oracles (a gathered row, functionally decoded) score
    the same distributions as the single-stream engine's peeks."""
    import numpy as np

    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=64, seed=5)
    single = SpeculativeEngine(tc, tp, dc, dp, ecfg)
    stream = single.new_stream([1, 2, 3])
    beng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=2)
    beng.submit([1, 2, 3], max_new=8, seed=5)
    # advance both one block with identical rng state, then peek
    beng.step()
    single.step(stream)
    bstream = next(iter(beng.streams.values()))
    assert bstream["committed"] == stream["committed"]
    for ctx in ([], [7], [7, 11]):
        np.testing.assert_allclose(beng.peek_target_dist(bstream, ctx),
                                   single.peek_target_dist(stream, ctx),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(beng.peek_draft_dist(bstream, ctx),
                                   single.peek_draft_dist(stream, ctx),
                                   rtol=1e-5, atol=1e-6)
    # peeks are functional: the pool state they read is unchanged
    assert bstream["committed"] == stream["committed"]


@pytest.mark.slow
def test_analytic_selector_runs_batched(dense_models):
    """AnalyticSelector's Eq. 9 argmax runs under continuous batching now
    that the engine provides pooled peek oracles (it used to be rejected
    at construction and silently unusable on pooled streams)."""
    from repro.core.delayed import LatencyModel
    from repro.serving.nde import AnalyticSelector

    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=64)
    sel = AnalyticSelector([(1, 1, 0), (2, 1, 1)],
                           LatencyModel(1e-4, 0.0, 1e-3, 0.0), "specinfer", s=1)
    beng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, selector=sel, n_slots=2)
    outs = beng.generate_batch([[1, 2, 3], [4, 5]], max_new=4, seeds=[1, 2])
    assert [len(o) for o in outs] == [4, 4]


def test_analytic_selector_fails_loud_without_peeks():
    """An engine without peek oracles must raise, not silently degrade the
    selection to a default action."""
    from repro.core.delayed import LatencyModel
    from repro.serving.nde import AnalyticSelector

    sel = AnalyticSelector([(2, 1, 1)], LatencyModel(1e-4, 0.0, 1e-3, 0.0),
                           "specinfer", s=1)
    with pytest.raises(TypeError, match="peek_draft_dist"):
        sel({"committed": [1, 2]}, object())


def test_long_prompt_prefill_does_not_wrap(dense_models):
    """Prompt-pad bucketing must cap at the ring size (regression: a
    21-token prompt in a 24-slot ring padded to 32 and wrapped onto its own
    committed prefix, silently corrupting the context), and prompts that
    cannot fit at all are rejected at submit."""
    tc, tp, dc, dp = dense_models
    prompt = list(range(1, 22))
    ecfg = EngineConfig(verifier="specinfer", K=1, L1=0, L2=1, max_cache=24)
    singles = _single_outputs(tc, tp, dc, dp, ecfg, [prompt], [7], max_new=2)
    beng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=1)
    assert beng.generate_batch([prompt], max_new=2, seeds=[7]) == singles
    with pytest.raises(ValueError):
        beng.submit(list(range(24)), max_new=2)


def test_counters_coherent(dense_models):
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    beng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4)
    beng.generate_batch(PROMPTS, max_new=12, seeds=SEEDS)
    c = beng.counters
    assert c["blocks"] > 0
    assert c["target_calls"] > 0
    # one padded tree pass per iteration advances every active stream:
    # strictly fewer target calls than blocks (the batching win)
    assert c["target_calls"] < c["blocks"]
    assert 0 <= c["accepted"] <= c["blocks"] * 3
