"""End-to-end speculative engine tests.

Invariants:
  * self-drafting (draft == target) accepts every drafted token for every
    verifier/strategy — block efficiency is exactly the tree depth + 1;
  * the engine's emitted first-token distribution matches direct target
    sampling (statistical, integration-level losslessness);
  * delayed expansion produces valid trees; counters are coherent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.transformer import forward, init_params
from repro.sampling import warp_logits
from repro.serving.engine import EngineConfig, SamplingParams, SpeculativeEngine

V = 32


def _dense(nl=2, dm=48, name="t", vocab=V):
    return ModelConfig(name=name, arch_type="dense", n_layers=nl, d_model=dm, n_heads=4,
                       n_kv_heads=2, d_ff=96, vocab=vocab, dtype="float32")


@pytest.fixture(scope="module")
def models():
    tc = _dense(2, 64)
    dc = _dense(1, 32, "d")
    return tc, init_params(tc, jax.random.PRNGKey(0)), dc, init_params(dc, jax.random.PRNGKey(1))


SSM_CFG = ModelConfig(name="s", arch_type="ssm", n_layers=2, d_model=48, vocab=V,
                      ssm_state=16, ssm_headdim=16, ssm_chunk=8, dtype="float32")
HYB_CFG = ModelConfig(name="h", arch_type="hybrid", n_layers=5, d_model=48, n_heads=4,
                      n_kv_heads=1, d_ff=96, vocab=V, local_window=32, dtype="float32")


@pytest.mark.slow
@pytest.mark.parametrize("cfg", [_dense(2, 48), SSM_CFG, HYB_CFG], ids=["dense", "ssm", "hybrid"])
@pytest.mark.parametrize("verifier,K,L1,L2,expect", [
    ("naive_single", 1, 0, 3, 4.0),
    ("bv", 1, 1, 2, 4.0),
    ("traversal", 2, 1, 1, 3.0),
    ("specinfer", 2, 1, 1, 3.0),
])
def test_self_draft_full_acceptance(cfg, verifier, K, L1, L2, expect):
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = SpeculativeEngine(cfg, params, cfg, params,
                            EngineConfig(verifier=verifier, K=K, L1=L1, L2=L2, max_cache=128))
    eng.generate([1, 2, 3], max_new=18)
    be = eng.counters["accepted"] / eng.counters["blocks"] + 1
    assert abs(be - expect) < 1e-6, be


@pytest.mark.slow
@pytest.mark.parametrize("verifier", ["specinfer", "traversal", "spectr", "khisti", "nss"])
def test_engine_first_token_distribution(models, verifier):
    """The first emitted token across many seeds must follow the warped target."""
    tc, tp, dc, dp = models
    prompt = [3, 1, 4]
    temp, topp = 0.9, 1.0
    # direct target distribution at the prompt
    logits, _, _ = forward(tp, tc, jnp.asarray([prompt]), mode="full")
    p_direct = np.asarray(warp_logits(logits[0, -1], temp, topp))

    n = 260
    counts = np.zeros(V)
    eng = SpeculativeEngine(tc, tp, dc, dp,
                            EngineConfig(verifier=verifier, K=2, L1=1, L2=1, max_cache=128),
                            SamplingParams(temp, topp))
    for seed in range(n):
        eng.rng = np.random.default_rng(seed)
        stream = eng.new_stream(list(prompt))
        toks = eng.step(stream)
        counts[toks[0]] += 1
    freq = counts / n
    # generous statistical tolerance (binomial std ~ sqrt(p/n) ~ 0.03)
    assert np.abs(freq - p_direct).max() < 0.09, np.abs(freq - p_direct).max()


@pytest.mark.slow
def test_counters_and_block_structure(models):
    tc, tp, dc, dp = models
    eng = SpeculativeEngine(tc, tp, dc, dp, EngineConfig(verifier="spectr", K=3, L1=2, L2=2, max_cache=256))
    out = eng.generate([5, 6], max_new=25)
    assert len(out) == 25
    c = eng.counters
    assert c["blocks"] == c["target_calls"]
    # every block drafts L1 + K*L2 tokens (+ delta ingestion)
    assert c["draft_tokens"] >= c["blocks"] * (2 + 3 * 2)
    assert 0 <= c["accepted"] <= c["blocks"] * 8


@pytest.mark.slow
def test_greedy_temperature_zero(models):
    """temperature=0 -> engine output equals greedy target decoding exactly."""
    tc, tp, dc, dp = models
    eng = SpeculativeEngine(tc, tp, dc, dp,
                            EngineConfig(verifier="specinfer", K=2, L1=1, L2=2, max_cache=128),
                            SamplingParams(temperature=0.0))
    out = eng.generate([2, 7], max_new=12)
    # direct greedy
    ctx = [2, 7]
    for _ in range(12):
        lg, _, _ = forward(tp, tc, jnp.asarray([ctx]), mode="full")
        ctx.append(int(jnp.argmax(lg[0, -1])))
    assert out == ctx[2:], (out, ctx[2:])


@pytest.mark.slow
def test_nucleus_sampling_support(models):
    """top_p < 1: emitted tokens must stay within the warped support."""
    tc, tp, dc, dp = models
    eng = SpeculativeEngine(tc, tp, dc, dp,
                            EngineConfig(verifier="traversal", K=2, L1=1, L2=1, max_cache=256),
                            SamplingParams(1.0, 0.7))
    stream = eng.new_stream([1, 2, 3])
    for _ in range(6):
        ctx = list(stream["committed"])
        toks = eng.step(stream)
        # each emitted token must lie in the nucleus of the target at its prefix
        for i, t in enumerate(toks):
            lg, _, _ = forward(tp, tc, jnp.asarray([ctx + toks[:i]]), mode="full")
            dist = np.asarray(warp_logits(lg[0, -1], 1.0, 0.7))
            assert dist[t] > 0, (t, i)


@pytest.mark.slow
def test_analytic_selector_runs(models):
    from repro.core.delayed import LatencyModel
    from repro.serving.nde import AnalyticSelector

    tc, tp, dc, dp = models
    sel = AnalyticSelector([(1, 1, 0), (2, 1, 1)], LatencyModel(1e-4, 0, 1e-3, 0), "specinfer")
    eng = SpeculativeEngine(tc, tp, dc, dp, EngineConfig(verifier="specinfer", max_cache=256),
                            selector=sel)
    out = eng.generate([1, 2], max_new=8)
    assert len(out) == 8
