"""Sampling warp tests: temperature/nucleus semantics."""
import jax
import jax.numpy as jnp
import numpy as np
from _propcheck import given, settings, strategies as st

from repro.sampling import sample_categorical, warp_logits, warp_probs


def test_temperature_zero_is_greedy():
    logits = jnp.asarray([0.1, 2.0, -1.0])
    d = warp_logits(logits, 0.0)
    np.testing.assert_array_equal(np.asarray(d), [0, 1, 0])


def test_temperature_scales_entropy():
    logits = jnp.asarray([1.0, 0.0, -1.0])
    hot = warp_logits(logits, 2.0)
    cold = warp_logits(logits, 0.5)

    def H(d):
        d = np.clip(np.asarray(d), 1e-12, None)
        return -(d * np.log(d)).sum()

    assert H(hot) > H(cold)


def test_nucleus_keeps_threshold_token():
    probs = jnp.asarray([0.5, 0.3, 0.15, 0.05])
    out = np.asarray(warp_probs(probs, top_p=0.6))
    # 0.5 < 0.6 so the second token (crossing the threshold) is kept
    assert out[0] > 0 and out[1] > 0 and out[2] == 0 and out[3] == 0
    assert abs(out.sum() - 1) < 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.1, 0.999))
def test_nucleus_mass_and_renorm(seed, top_p):
    rng = np.random.default_rng(seed)
    p = rng.dirichlet(np.ones(8)).astype(np.float32)
    out = np.asarray(warp_probs(jnp.asarray(p), top_p=top_p))
    assert abs(out.sum() - 1) < 1e-5
    kept = out > 0
    # kept mass under the ORIGINAL distribution covers top_p
    assert p[kept].sum() >= top_p - 1e-6


def test_sample_categorical_distribution():
    key = jax.random.PRNGKey(0)
    probs = jnp.asarray([0.7, 0.0, 0.3])
    keys = jax.random.split(key, 4000)
    s = jax.vmap(lambda k: sample_categorical(k, probs))(keys)
    counts = np.bincount(np.asarray(s), minlength=3) / 4000
    assert counts[1] == 0
    assert abs(counts[0] - 0.7) < 0.03
