"""Concurrent shard stepping: the dispatch-order and identity contract.

``ShardedBatchedSpeculativeEngine.step`` runs in phases: every shard's
``begin_step`` dispatches its draft + target-tree device work FIRST, and
only then does any shard's verify phase block on a result.  The per-stream
host verify loop of each shard therefore hides behind the other shards'
in-flight device work instead of serializing the shards end to end (the
regression that made the 2-shard bench row slower than one shard).

These tests pin that contract the same way test_pipeline.py pins the
single-engine overlap:

  * a call-order probe (instance-wrapped ``begin_step``/``verify_step``
    hooks) asserting that with N shards, all N begin dispatches happen
    before the first shard's verify completes — in BOTH stepping modes;
  * token identity sync == pipelined == sharded == sharded-pipelined for
    both target-pass strategies x both verifiers under the concurrent
    path (seeded, so any reordering of effectful host work would show).
"""
import jax
import pytest

from repro.models.config import ModelConfig
from repro.models.transformer import init_params
from repro.serving.batch_engine import (
    BatchedSpeculativeEngine,
    ShardedBatchedSpeculativeEngine,
)
from repro.serving.engine import EngineConfig

V = 32

DENSE_T = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")
DENSE_D = ModelConfig(name="d", arch_type="dense", n_layers=1, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")
SSM_CFG = ModelConfig(name="s", arch_type="ssm", n_layers=2, d_model=48, vocab=V,
                      ssm_state=16, ssm_headdim=16, ssm_chunk=8, dtype="float32")

PROMPTS = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [3, 1]]
SEEDS = [20, 21, 22, 23]


@pytest.fixture(scope="module")
def dense_models():
    return (DENSE_T, init_params(DENSE_T, jax.random.PRNGKey(0)),
            DENSE_D, init_params(DENSE_D, jax.random.PRNGKey(1)))


@pytest.fixture(scope="module")
def ssm_params():
    return init_params(SSM_CFG, jax.random.PRNGKey(0))


# --------------------------------------------------- dispatch-order probe ---


def _probe(eng):
    """Instance-wrap every shard's begin/verify so the log records the
    interleaving the phased step actually produced."""
    log = []
    for si, sh in enumerate(eng.shards):
        def _wrap(si, sh):
            begin0, verify0 = sh.begin_step, sh.verify_step

            def begin(*a, **kw):
                pending = begin0(*a, **kw)
                log.append(("begin", si))
                return pending

            def verify(*a, **kw):
                v = verify0(*a, **kw)
                log.append(("verify_done", si))
                return v

            sh.begin_step, sh.verify_step = begin, verify
        _wrap(si, sh)
    return log


@pytest.mark.parametrize("pipeline", [False, True], ids=["sync", "pipelined"])
def test_all_begins_dispatch_before_first_verify_completes(dense_models, pipeline):
    """The acceptance probe for concurrent shard stepping: on a cold step
    with N shards holding streams, all N ``begin_step`` dispatches are
    issued before the FIRST shard's verify phase completes (a verify is the
    first point a shard's finish work blocks on its device result)."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    eng = ShardedBatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=2,
                                          data_shards=2, pipeline=pipeline)
    log = _probe(eng)
    r0 = eng.submit([1, 2, 3], max_new=8, seed=20)
    r1 = eng.submit([4, 5], max_new=8, seed=21)
    assert [eng.shard_of(r) for r in (r0, r1)] == [0, 1]
    eng.step()
    first_verify = log.index(("verify_done", 0))
    begun = {si for kind, si in log[:first_verify] if kind == "begin"}
    assert begun == {0, 1}, f"sequential shard stepping resurfaced: {log}"
    eng.run()  # drain; identity is pinned by the tests below


# -------------------------------------------------------- token identity ---

MODES = {
    "pipelined": {"pipeline": True},
    "sharded": {"data_shards": 2},
    "sharded-pipelined": {"data_shards": 2, "pipeline": True},
}


@pytest.fixture(scope="module")
def sync_ref(dense_models, ssm_params):
    """Synchronous-engine reference outputs, built once per (strategy,
    verifier) and shared across the mode matrix — each identity test then
    pays for exactly one engine build."""
    cache = {}

    def get(strategy, verifier):
        key = (strategy, verifier)
        if key not in cache:
            (tc, tp, dc, dp), n, mn = _setup(dense_models, ssm_params, strategy)
            ecfg = EngineConfig(verifier=verifier, K=2, L1=1, L2=1, max_cache=128)
            eng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=n)
            assert eng.strategy == strategy
            cache[key] = eng.generate_batch(PROMPTS[:n], max_new=mn,
                                            seeds=SEEDS[:n])
        return cache[key]

    return get


def _setup(dense_models, ssm_params, strategy):
    if strategy == "tree":
        return dense_models, 4, 12
    return (SSM_CFG, ssm_params, SSM_CFG, ssm_params), 2, 6


@pytest.mark.parametrize("verifier", ["specinfer", "traversal"])
@pytest.mark.parametrize("mode", list(MODES))
def test_identity_tree(dense_models, ssm_params, sync_ref, mode, verifier):
    """sync == pipelined == sharded == sharded-pipelined (tree strategy)."""
    models, n, mn = _setup(dense_models, ssm_params, "tree")
    ecfg = EngineConfig(verifier=verifier, K=2, L1=1, L2=1, max_cache=128)
    cls = ShardedBatchedSpeculativeEngine if "data_shards" in MODES[mode] \
        else BatchedSpeculativeEngine
    eng = cls(*models, ecfg, n_slots=n, **MODES[mode])
    assert eng.strategy == "tree"
    assert eng.generate_batch(PROMPTS[:n], max_new=mn, seeds=SEEDS[:n]) \
        == sync_ref("tree", verifier)


@pytest.mark.parametrize("verifier", ["specinfer", "traversal"])
@pytest.mark.parametrize("mode", list(MODES))
def test_identity_replay(dense_models, ssm_params, sync_ref, mode, verifier):
    """Same contract for the replay strategy (recurrent target): the
    host-interleaved re-advance rides the concurrent phases unchanged."""
    models, n, mn = _setup(dense_models, ssm_params, "replay")
    ecfg = EngineConfig(verifier=verifier, K=2, L1=1, L2=1, max_cache=128)
    cls = ShardedBatchedSpeculativeEngine if "data_shards" in MODES[mode] \
        else BatchedSpeculativeEngine
    eng = cls(*models, ecfg, n_slots=n, **MODES[mode])
    assert eng.strategy == "replay"
    assert eng.generate_batch(PROMPTS[:n], max_new=mn, seeds=SEEDS[:n]) \
        == sync_ref("replay", verifier)
