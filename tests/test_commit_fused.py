"""Fused-commit equivalence: the device-resident commit path's contract.

Property tests (vendored _propcheck shim) that the one-call batched commit
(serve_step.make_pool_commit_step + kernels/commit_kv) leaves the pool
bit-identical to the per-row PR-1 commit chain
(serve_step.commit_row_reference) across random accepted paths, ring-wrap
positions and mixed active/idle slots — for the tree strategy's scatter and
for the replay strategy's fused row write-back — plus the engine-level
guarantee that the commit path issues exactly ONE jitted call per step()
regardless of the active-stream count.
"""
import types

import jax
import jax.numpy as jnp
import numpy as np
from _propcheck import given, settings, strategies as st

from repro.core.trees import tree_ancestor_mask
from repro.kernels.commit_kv import commit_kv
from repro.kernels.ref import commit_kv_ref
from repro.models.cache import concat_streams, scatter_streams
from repro.models.config import ModelConfig
from repro.models.transformer import init_params
from repro.serving.batch_engine import BatchedSpeculativeEngine
from repro.serving.engine import EngineConfig, SpeculativeEngine
from repro.serving.serve_step import (
    commit_row_reference,
    device_ancestor_mask,
    make_pool_commit_step,
    next_pow2,
)

L, B, S, H, HD = 2, 4, 16, 2, 4


def _rand_pool(rng):
    return {
        "attn": {
            "k": jnp.asarray(rng.normal(size=(L, B, S, H, HD)).astype(np.float32)),
            "v": jnp.asarray(rng.normal(size=(L, B, S, H, HD)).astype(np.float32)),
            "pos": jnp.asarray(rng.integers(-1, 4 * S, size=(B, S)).astype(np.int32)),
            "len": jnp.asarray(rng.integers(0, 4 * S, size=(B,)).astype(np.int32)),
        }
    }


def _rand_case(rng, Tpad):
    """Random per-row commit inputs honouring the index contract: accepted
    node indices strictly increasing in (0, Tpad), C anywhere in the ring
    (including past S, exercising the modulo wrap)."""
    paths, Cs, act = {}, {}, {}
    for b in range(B):
        act[b] = bool(rng.integers(2))
        tau = int(rng.integers(0, Tpad))
        paths[b] = sorted(rng.choice(np.arange(1, Tpad), size=tau, replace=False).tolist()) if tau else []
        Cs[b] = int(rng.integers(1, 3 * S))
    return paths, Cs, act


def _fused(pool, paths, Cs, act, Tpad, attention_impl):
    cfg = types.SimpleNamespace(attention_impl=attention_impl, kernel_interpret=True)
    P = next_pow2(max([len(p) for b, p in paths.items() if act[b]] + [1]))
    npath = np.zeros((B, P), np.int32)
    plen = np.zeros((B,), np.int32)
    C = np.zeros((B,), np.int32)
    active = np.zeros((B,), np.bool_)
    for b in range(B):
        if not act[b]:
            continue
        npath[b, : len(paths[b])] = paths[b]
        plen[b] = len(paths[b])
        C[b] = Cs[b]
        active[b] = True
    commit = make_pool_commit_step(cfg, Tpad)
    return commit(pool, jnp.asarray(npath), jnp.asarray(plen), jnp.asarray(C),
                  jnp.asarray(active))


def _assert_pools_equal(got, want):
    for key in ("k", "v", "pos", "len"):
        assert np.array_equal(np.asarray(got["attn"][key]), np.asarray(want["attn"][key])), key


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 8))
def test_fused_commit_matches_per_row(seed, Tpad):
    rng = np.random.default_rng(seed)
    pool = _rand_pool(rng)
    paths, Cs, act = _rand_case(rng, Tpad)
    ref = pool
    for b in range(B):
        if act[b]:
            ref = commit_row_reference(ref, b, Cs[b], paths[b], Tpad)
    got = _fused(pool, paths, Cs, act, Tpad, "xla")
    _assert_pools_equal(got, ref)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 6))
def test_fused_commit_pallas_kernel_path(seed, Tpad):
    """The Pallas commit_kv route (interpret mode) is bit-identical too."""
    rng = np.random.default_rng(seed)
    pool = _rand_pool(rng)
    paths, Cs, act = _rand_case(rng, Tpad)
    ref = pool
    for b in range(B):
        if act[b]:
            ref = commit_row_reference(ref, b, Cs[b], paths[b], Tpad)
    got = _fused(pool, paths, Cs, act, Tpad, "pallas")
    _assert_pools_equal(got, ref)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 6))
def test_commit_kv_kernel_matches_ref(seed, P):
    """kernels/commit_kv (sequential in-place grid) == gather-then-scatter
    oracle on hazard-free index tables (src disjoint from other dsts)."""
    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.normal(size=(L, B, S, H, HD)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(L, B, S, H, HD)).astype(np.float32))
    src = np.zeros((B, P), np.int32)
    dst = np.zeros((B, P), np.int32)
    for b in range(B):
        C = int(rng.integers(0, 3 * S))
        tau = int(rng.integers(0, P + 1))
        nodes = np.sort(rng.choice(np.arange(1, S), size=tau, replace=False)) if tau else []
        for j in range(P):
            if j < tau:  # strictly-increasing nodes from 1 => nodes[j] >= j+1
                src[b, j] = (C + int(nodes[j])) % S
                dst[b, j] = (C + 1 + j) % S
            else:
                src[b, j] = dst[b, j] = C % S
    ko, vo = commit_kv(k, v, jnp.asarray(src), jnp.asarray(dst), interpret=True)
    kr, vr = commit_kv_ref(k, v, jnp.asarray(src), jnp.asarray(dst))
    assert np.array_equal(np.asarray(ko), np.asarray(kr))
    assert np.array_equal(np.asarray(vo), np.asarray(vr))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 12))
def test_device_ancestor_mask_matches_host(seed, T):
    """Device-composed eye/ancestor masks == host tree_ancestor_mask per row,
    with padding rows (parent = -1 everywhere) as isolated roots."""
    rng = np.random.default_rng(seed)
    parents = np.full((B, T), -1, np.int32)
    want = np.zeros((B, T, T), bool)
    for b in range(B):
        n = int(rng.integers(1, T + 1))
        par = [-1] + [int(rng.integers(0, i)) for i in range(1, n)]
        parents[b, :n] = par
        want[b] = np.eye(T, dtype=bool)
        want[b, :n, :n] = tree_ancestor_mask(np.asarray(par))
    got = np.asarray(device_ancestor_mask(jnp.asarray(parents)))
    assert np.array_equal(got, want)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_fused_row_scatter_matches_sequential(seed):
    """Replay-strategy commit write: concat_streams + one scatter_streams ==
    the PR-1 per-group scatter chain (mixed row groups, ssm-style cache)."""
    rng = np.random.default_rng(seed)
    pool = {
        "state": jnp.asarray(rng.normal(size=(L, B, 3, 5)).astype(np.float32)),
        "conv": jnp.asarray(rng.normal(size=(L, B, 2, 7)).astype(np.float32)),
        "len": jnp.asarray(rng.integers(0, 50, size=(B,)).astype(np.int32)),
    }
    rows = [int(r) for r in rng.permutation(B)[: int(rng.integers(1, B + 1))]]
    cut = int(rng.integers(0, len(rows) + 1))
    groups = [g for g in (rows[:cut], rows[cut:]) if g]
    subs = []
    for g in groups:
        subs.append({
            "state": jnp.asarray(rng.normal(size=(L, len(g), 3, 5)).astype(np.float32)),
            "conv": jnp.asarray(rng.normal(size=(L, len(g), 2, 7)).astype(np.float32)),
            "len": jnp.asarray(rng.integers(0, 50, size=(len(g),)).astype(np.int32)),
        })
    seq = pool
    for g, sub in zip(groups, subs):
        seq = scatter_streams(seq, sub, g)
    combined = subs[0] if len(subs) == 1 else concat_streams(subs)
    fused = scatter_streams(pool, combined, [r for g in groups for r in g])
    for key in pool:
        assert np.array_equal(np.asarray(fused[key]), np.asarray(seq[key])), key


# ------------------------------------------------------- engine-level ---

V = 32
DENSE_T = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")
DENSE_D = ModelConfig(name="d", arch_type="dense", n_layers=1, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")


def test_one_commit_call_per_step():
    """Acceptance: the commit path issues exactly one jitted call per step()
    regardless of the active-stream count — counted both by the engine's
    commit counter and by its jit cache (one entry per shape bucket, not one
    per stream)."""
    tc, dc = DENSE_T, DENSE_D
    tp = init_params(tc, jax.random.PRNGKey(0))
    dp = init_params(dc, jax.random.PRNGKey(1))
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    for prompts in ([[1, 2, 3]], [[1, 2, 3], [4, 5], [6, 7, 8, 9]]):
        beng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4)
        for i, p in enumerate(prompts):
            beng.submit(p, max_new=12, seed=20 + i)
        n_steps = 0
        while beng.queue or beng.streams:
            if beng.step():
                n_steps += 1
        assert beng.counters["commit_calls"] == n_steps
        commit_entries = [k for k in beng._jit_cache if k.startswith("commit_")]
        # shape buckets only — independent of how many streams were resident
        assert 1 <= len(commit_entries) <= 3, commit_entries
        assert beng.counters["commit_ms"] > 0.0


def test_single_engine_commit_routed_through_primitive():
    """SpeculativeEngine commits through the same fused primitive: its jit
    cache gains commit_* entries and generation still works."""
    tc, dc = DENSE_T, DENSE_D
    tp = init_params(tc, jax.random.PRNGKey(0))
    dp = init_params(dc, jax.random.PRNGKey(1))
    eng = SpeculativeEngine(tc, tp, dc, dp,
                            EngineConfig(verifier="specinfer", K=2, L1=1, L2=1,
                                         max_cache=128, seed=5))
    out = eng.generate([1, 2, 3], max_new=8)
    assert len(out) >= 8
    assert any(k.startswith("commit_") for k in eng._jit_cache)
