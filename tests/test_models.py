"""Model substrate tests: every assigned architecture's reduced config runs a
forward pass + one train step on CPU (shape + NaN assertions), and the decode
path is consistent with the full pass (exact in fp32)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke, list_arches
from repro.models.transformer import forward, init_cache, init_params, make_train_step
from repro.training.optim import AdamW

B, T = 2, 16


def _batch_kwargs(cfg, rng):
    kw = {}
    if cfg.arch_type == "encdec":
        kw["enc_embeds"] = jnp.asarray(rng.standard_normal((B, cfg.enc_len, cfg.d_model)), cfg.jdtype)
    if cfg.arch_type == "vlm":
        kw["embeds"] = jnp.asarray(rng.standard_normal((B, cfg.n_patches, cfg.d_model)), cfg.jdtype)
    return kw


@pytest.mark.slow
@pytest.mark.parametrize("arch", list_arches())
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    kw = _batch_kwargs(cfg, rng)
    logits, _, extras = forward(params, cfg, toks, mode="full", **kw)
    exp_T = T + (cfg.n_patches if cfg.arch_type == "vlm" else 0)
    assert logits.shape == (B, exp_T, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1).at[:, -1].set(-1), **kw}
    params2, _, loss = step(params, opt.init(params), batch)
    assert np.isfinite(float(loss))
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert not np.allclose(np.asarray(l0, np.float32), np.asarray(l1, np.float32))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["granite-8b", "qwen3-moe-235b-a22b", "mamba2-2.7b",
                                  "recurrentgemma-2b", "whisper-medium", "internvl2-26b"])
def test_decode_consistency(arch):
    cfg = get_smoke(arch).replace(dtype="float32")
    rng = np.random.default_rng(1)
    params = init_params(cfg, jax.random.PRNGKey(1))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    kw = _batch_kwargs(cfg, rng)
    cache = init_cache(cfg, B, 64)
    lg, cache, _ = forward(params, cfg, toks, mode="full", cache=cache, **kw)
    nxt = jnp.argmax(lg[:, -1:], -1)
    lg2, cache, _ = forward(params, cfg, nxt, mode="decode", cache=cache)
    toks2 = jnp.concatenate([toks, nxt], 1)
    lg_full, _, _ = forward(params, cfg, toks2, mode="full", **kw)
    if cfg.arch_type == "vlm":
        lg_full = lg_full[:, cfg.n_patches:]
    err = float(jnp.abs(lg2[:, -1] - lg_full[:, -1]).max())
    assert err < 2e-4, err


def test_tree_mode_matches_sequential_decode():
    """A path-shaped 'tree' pass must equal sequential decode exactly."""
    cfg = get_smoke("granite-8b").replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    chain = rng.integers(0, cfg.vocab, 4)

    cache = init_cache(cfg, 1, 64)
    _, cache, _ = forward(params, cfg, prompt, mode="full", cache=cache)
    anc = jnp.asarray(np.tril(np.ones((4, 4), bool)))
    lg_tree, _, _ = forward(
        params, cfg, jnp.asarray(chain[None], jnp.int32), mode="tree", cache=cache, anc=anc
    )

    cache2 = init_cache(cfg, 1, 64)
    _, cache2, _ = forward(params, cfg, prompt, mode="full", cache=cache2)
    lg_seq, _, _ = forward(params, cfg, jnp.asarray(chain[None], jnp.int32), mode="decode", cache=cache2)
    np.testing.assert_allclose(np.asarray(lg_tree), np.asarray(lg_seq), atol=1e-4)


def test_tree_mode_branch_isolation():
    """Sibling branches must not attend to each other: the logits of branch A
    must be identical whatever tokens branch B holds."""
    cfg = get_smoke("granite-8b").replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    # tree: root(0) -> a(1), root -> b(2): anc masks
    anc = jnp.asarray(np.array([[1, 0, 0], [1, 1, 0], [1, 0, 1]], bool))
    base = np.asarray([5, 7, 9], np.int32)

    def run(tok_b):
        cache = init_cache(cfg, 1, 64)
        _, cache, _ = forward(params, cfg, prompt, mode="full", cache=cache)
        toks = base.copy()
        toks[2] = tok_b
        lg, _, _ = forward(params, cfg, jnp.asarray(toks[None]), mode="tree", cache=cache, anc=anc)
        return np.asarray(lg[0, 1])

    np.testing.assert_allclose(run(9), run(123), atol=1e-5)


def test_sliding_window_limits_attention():
    cfg = get_smoke("qwen2-72b").replace(dtype="float32", attention="sliding_window", window=4)
    params = init_params(cfg, jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    toks = np.asarray(rng.integers(0, cfg.vocab, (1, 12)), np.int32)
    lg1, _, _ = forward(params, cfg, jnp.asarray(toks), mode="full")
    toks2 = toks.copy()
    toks2[0, 0] = (toks2[0, 0] + 1) % cfg.vocab  # outside the window of pos 11
    lg2, _, _ = forward(params, cfg, jnp.asarray(toks2), mode="full")
    np.testing.assert_allclose(np.asarray(lg1[0, -1]), np.asarray(lg2[0, -1]), atol=1e-5)


def test_param_counts_match_assignment_scale():
    """Full configs should be in the right parameter ballpark."""
    from repro.configs import get_config

    expect = {
        "granite-8b": (7e9, 10e9),
        "qwen2-72b": (65e9, 80e9),
        "granite-3-2b": (2e9, 4e9),
        "mamba2-2.7b": (2e9, 3.5e9),
        "recurrentgemma-2b": (2e9, 3.6e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "llama4-maverick-400b-a17b": (350e9, 450e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
