"""Paged KV pool: token-identity with the PR-1 ring pool + block lifecycle.

The paged pool (models/cache.py paged layout) must be a pure indirection:
with ring-equivalent capacity the engine's scheduling is unchanged and the
emitted tokens are identical to the ring pool for every verifier and both
target-pass strategies, across admissions, capacity evictions and commit
ring-wraps.  On top of that, the block lifecycle — admission gating on the
free list, dead-tail reclamation, LIFO pressure eviction — must let long
and short streams co-reside in an arena the ring design could not share.
"""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.models.cache import (
    PagedCachePool,
    concat_streams,
    fork_streams,
    gather_streams,
    init_paged_attn_cache,
    merge_streams,
    scatter_streams,
)
from repro.models.config import ModelConfig
from repro.models.transformer import init_cache, init_params
from repro.serving.batch_engine import BatchedSpeculativeEngine
from repro.serving.engine import EngineConfig
from repro.serving.serve_step import make_pool_commit_step, next_pow2

V = 32
DENSE_T = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")
DENSE_D = ModelConfig(name="d", arch_type="dense", n_layers=1, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")
HYB_CFG = ModelConfig(name="h", arch_type="hybrid", n_layers=5, d_model=48, n_heads=4,
                      n_kv_heads=1, d_ff=96, vocab=V, local_window=32, dtype="float32")

PROMPTS = [[1, 2, 3], [4, 5], [6, 7, 8, 9]]
SEEDS = [20, 21, 22]


@pytest.fixture(scope="module")
def dense_models():
    return (DENSE_T, init_params(DENSE_T, jax.random.PRNGKey(0)),
            DENSE_D, init_params(DENSE_D, jax.random.PRNGKey(1)))


def _outputs(tc, tp, dc, dp, ecfg, prompts, seeds, max_new, selector=None, **pool_kw):
    eng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, selector=selector,
                                   n_slots=4, **pool_kw)
    return eng, eng.generate_batch(prompts, max_new=max_new, seeds=seeds)


# ------------------------------------------------------ engine token-identity ---


@pytest.mark.parametrize("verifier", ["specinfer", "traversal"])
def test_paged_matches_ring_tree_strategy(dense_models, verifier):
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier=verifier, K=2, L1=1, L2=1, max_cache=128)
    _, ring = _outputs(tc, tp, dc, dp, ecfg, PROMPTS, SEEDS, 16, paged=False)
    peng, paged = _outputs(tc, tp, dc, dp, ecfg, PROMPTS, SEEDS, 16,
                           paged=True, block_size=8)
    assert peng.paged and isinstance(peng.tpool, PagedCachePool)
    assert paged == ring
    # the pool never materialized the ring-equivalent footprint
    assert 0 < peng.counters["blocks_peak"] < peng.pool_blocks


@pytest.mark.slow
@pytest.mark.parametrize("verifier", ["specinfer", "traversal"])
def test_paged_matches_ring_replay_strategy(verifier):
    """Hybrid arch: the replay strategy's grouped gathers/scatters and forks
    route through the paged attn component (recurrent state stays dense)."""
    params = init_params(HYB_CFG, jax.random.PRNGKey(0))
    ecfg = EngineConfig(verifier=verifier, K=2, L1=1, L2=1, max_cache=128)
    reng, ring = _outputs(HYB_CFG, params, HYB_CFG, params, ecfg, PROMPTS, SEEDS, 10,
                          paged=False)
    peng, paged = _outputs(HYB_CFG, params, HYB_CFG, params, ecfg, PROMPTS, SEEDS, 10,
                           paged=True, block_size=16)
    assert reng.strategy == peng.strategy == "replay"
    assert peng.paged
    assert paged == ring


@pytest.mark.slow
def test_paged_matches_ring_under_capacity_eviction(dense_models):
    """A stream that outgrows its logical ring is evicted at the same point
    with the same partial output under both layouts."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=24)
    ring = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=2, paged=False)
    rid = ring.submit([1, 2, 3], max_new=64, seed=7)
    ring_info = ring.run()[rid]
    paged = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=2,
                                     paged=True, block_size=8)
    rid = paged.submit([1, 2, 3], max_new=64, seed=7)
    info = paged.run()[rid]
    assert ring_info["reason"].startswith("evicted")
    assert info == ring_info


# -------------------------------------------------------- commit equivalence ---

L, B, S, H, HD = 2, 4, 16, 2, 4
BLK = 4
NB_PER = S // BLK


def _paired_pools(rng):
    """A dense per-stream pool and a paged pool with identical logical
    content: every row fully mapped through a random disjoint block table."""
    kd = rng.normal(size=(L, B, S, H, HD)).astype(np.float32)
    vd = rng.normal(size=(L, B, S, H, HD)).astype(np.float32)
    pos = rng.integers(-1, 4 * S, size=(B, S)).astype(np.int32)
    ln = rng.integers(0, 4 * S, size=(B,)).astype(np.int32)
    dense = {"attn": {"k": jnp.asarray(kd), "v": jnp.asarray(vd),
                      "pos": jnp.asarray(pos), "len": jnp.asarray(ln)}}
    perm = rng.permutation(np.arange(1, B * NB_PER + 1))
    tbl = perm.reshape(B, NB_PER).astype(np.int32)
    ka = np.zeros((L, B * NB_PER + 1, BLK, H, HD), np.float32)
    va = np.zeros_like(ka)
    for b in range(B):
        for i in range(NB_PER):
            ka[:, tbl[b, i]] = kd[:, b, i * BLK:(i + 1) * BLK]
            va[:, tbl[b, i]] = vd[:, b, i * BLK:(i + 1) * BLK]
    paged = {"attn": {"k": jnp.asarray(ka), "v": jnp.asarray(va),
                      "block_tbl": jnp.asarray(tbl), "pos": jnp.asarray(pos),
                      "len": jnp.asarray(ln)}}
    return dense, paged


def _logical(cache):
    got = gather_streams(cache, np.arange(B))["attn"]
    return {key: np.asarray(got[key]) for key in ("k", "v", "pos", "len")}


def _commit_args(rng, Tpad):
    paths, Cs, act = {}, {}, {}
    for b in range(B):
        act[b] = bool(rng.integers(2))
        tau = int(rng.integers(0, Tpad))
        paths[b] = (sorted(rng.choice(np.arange(1, Tpad), size=tau, replace=False).tolist())
                    if tau else [])
        Cs[b] = int(rng.integers(1, 3 * S))  # C past S exercises the ring wrap
    P = next_pow2(max([len(p) for b, p in paths.items() if act[b]] + [1]))
    npath = np.zeros((B, P), np.int32)
    plen = np.zeros((B,), np.int32)
    C = np.zeros((B,), np.int32)
    active = np.zeros((B,), np.bool_)
    for b in range(B):
        if act[b]:
            npath[b, :len(paths[b])] = paths[b]
            plen[b] = len(paths[b])
            C[b] = Cs[b]
            active[b] = True
    return tuple(jnp.asarray(a) for a in (npath, plen, C, active))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 8))
def test_paged_commit_matches_dense(seed, Tpad):
    """The fused commit through the block table leaves the paged pool's
    LOGICAL view bit-identical to the dense per-stream commit — including
    C > Smax ring wraps and idle rows."""
    rng = np.random.default_rng(seed)
    dense, paged = _paired_pools(rng)
    args = _commit_args(rng, Tpad)
    cfg = types.SimpleNamespace(attention_impl="xla", kernel_interpret=True)
    commit = make_pool_commit_step(cfg, Tpad)
    want = _logical(commit(dense, *args))
    got = _logical(commit(paged, *args))
    for key in want:
        assert np.array_equal(got[key], want[key]), key


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 6))
def test_paged_commit_pallas_kernel_path(seed, Tpad):
    """The Pallas commit_kv route over the flattened arena agrees too."""
    rng = np.random.default_rng(seed)
    dense, paged = _paired_pools(rng)
    args = _commit_args(rng, Tpad)
    xla = types.SimpleNamespace(attention_impl="xla", kernel_interpret=True)
    pal = types.SimpleNamespace(attention_impl="pallas", kernel_interpret=True)
    want = _logical(make_pool_commit_step(xla, Tpad)(dense, *args))
    got = _logical(make_pool_commit_step(pal, Tpad)(paged, *args))
    for key in want:
        assert np.array_equal(got[key], want[key]), key


# ------------------------------------------------------------ stream algebra ---


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_paged_stream_algebra_matches_dense(seed):
    """gather (dense view), scatter round-trip, fork and merge on a paged
    pool reproduce the dense pool's logical state exactly — including rows
    with different mapped-block counts fused by concat_streams."""
    rng = np.random.default_rng(seed)
    dense, paged = _paired_pools(rng)
    # unmap a random tail per row: rows now hold DIFFERENT block counts
    tbl = np.asarray(paged["attn"]["block_tbl"]).copy()
    pos = np.asarray(paged["attn"]["pos"]).copy()
    kd = np.asarray(dense["attn"]["k"]).copy()
    vd = np.asarray(dense["attn"]["v"]).copy()
    for b in range(B):
        keep = int(rng.integers(1, NB_PER + 1))
        tbl[b, keep:] = -1
        pos[b, keep * BLK:] = -1  # unmapped slots carry no live tokens
        kd[:, b, keep * BLK:] = 0  # dense mirror: zero the dropped content
        vd[:, b, keep * BLK:] = 0
    paged["attn"]["block_tbl"] = jnp.asarray(tbl)
    paged["attn"]["pos"] = jnp.asarray(pos)
    dense["attn"]["pos"] = jnp.asarray(pos)

    rows = [int(r) for r in rng.permutation(B)[: int(rng.integers(2, B + 1))]]
    cut = int(rng.integers(1, len(rows)))
    ga, gb = gather_streams(paged, rows[:cut]), gather_streams(paged, rows[cut:])
    # dense sub-rows of a paged pool concat like any other (different mapped
    # counts just mean trailing pos = -1 padding)
    combined = concat_streams([ga, gb])
    back = scatter_streams(paged, combined, rows)
    gl = _logical(back)
    # scatter of self-gathered rows is the identity on mapped lanes
    pos_np = np.asarray(paged["attn"]["pos"])
    assert np.array_equal(gl["pos"], pos_np)
    mapped = np.repeat(tbl >= 0, BLK, axis=1)  # (B, S)
    want_k = np.asarray(gather_streams(paged, np.arange(B))["attn"]["k"])
    assert np.array_equal(gl["k"][:, mapped], want_k[:, mapped])

    # fork materializes the dense view, replicated K times
    fork = fork_streams(paged, 2)
    dview = gather_streams(paged, np.arange(B))
    assert fork["attn"]["k"].shape[1] == 2 * B
    assert np.array_equal(np.asarray(fork["attn"]["k"][:, 0::2]),
                          np.asarray(dview["attn"]["k"]))

    # merge freezes non-keep rows at block granularity
    keep = rng.integers(0, 2, size=B).astype(bool)
    keep[int(rng.integers(B))] = True
    new = {"attn": dict(paged["attn"])}
    new["attn"]["k"] = paged["attn"]["k"] + 1.0
    new["attn"]["v"] = paged["attn"]["v"] + 1.0
    new["attn"]["pos"] = paged["attn"]["pos"] + 1
    merged = merge_streams(new, paged, keep)
    ml = _logical(merged)
    base = _logical(paged)
    for b in range(B):
        sel = mapped[b]
        if keep[b]:
            assert np.array_equal(ml["k"][:, b, sel], base["k"][:, b, sel] + 1.0)
            assert np.array_equal(ml["pos"][b], base["pos"][b] + 1)
        else:
            assert np.array_equal(ml["k"][:, b, sel], base["k"][:, b, sel])
            assert np.array_equal(ml["pos"][b], base["pos"][b])


# ---------------------------------------------------------- block lifecycle ---


def test_pool_block_bookkeeping():
    cfg = DENSE_T
    attn = init_paged_attn_cache(cfg, cfg.n_layers, 2, 6, 4, 16, jnp.float32)
    pool = PagedCachePool({"attn": attn}, 2)
    assert pool.total_blocks == 6 and pool.free_blocks == 6
    row = init_cache(cfg, 1, 16, per_stream=True)
    s0 = pool.admit(row, ctx_len=5)  # 2 blocks
    s1 = pool.admit(row, ctx_len=1)  # 1 block
    assert (pool.free_blocks, pool.used_blocks) == (3, 3)
    assert pool.missing_blocks(s0, 13) == 2 and pool.ensure(s0, 13)
    assert pool.free_blocks == 1
    assert not pool.ensure(s1, 16)  # needs 3 more, only 1 free — refused whole
    assert pool.free_blocks == 1
    assert pool.reclaim_tail(s0, 7) == 2  # frontier back to 2 blocks
    assert pool.ensure(s1, 9)
    occ = pool.occupancy({s0: 7, s1: 9})
    assert occ["blocks_used"] == 5 and occ["blocks_free"] == 1
    assert 0.0 <= occ["fragmentation"] < 1.0
    pool.release(s0)
    assert pool.free_blocks == 3
    # the trash block is never handed out
    assert 0 not in pool._free_blocks


def test_admission_blocks_until_blocks_free(dense_models):
    """Satellite: a request whose context + speculation bucket exceeds the
    free list stays queued (not admitted, not lost) and is admitted once a
    resident stream releases its blocks — outputs unchanged vs. the ring."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=64)
    ring = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=2, paged=False)
    prompts = [[1, 2, 3, 4, 5, 6, 7, 8], [8, 7, 6, 5, 4, 3, 2, 1]]
    seeds, max_news = [30, 31], [4, 4]
    rids = [ring.submit(p, max_new=m, seed=sd) for p, sd, m in zip(prompts, seeds, max_news)]
    want = ring.run()
    # 2 blocks of 8: admission asks for ceil((8 + Tpad0)/8) = 2 blocks per
    # stream, so the second request must wait until the first releases —
    # but each stream alone fits the arena, so nothing is ever evicted
    eng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=2,
                                   paged=True, block_size=8, pool_blocks=2)
    rids_p = [eng.submit(p, max_new=m, seed=sd) for p, sd, m in zip(prompts, seeds, max_news)]
    eng.step()
    assert len(eng.streams) == 1, "second stream must wait for blocks"
    assert eng.counters["admit_blocked"] > 0
    got = eng.run()
    assert eng.counters["evicted"] == 0
    assert [got[r]["tokens"] for r in rids_p] == [want[r]["tokens"] for r in rids]
    assert eng.tpool.free_blocks == eng.tpool.total_blocks


def test_midstream_tail_reclaim_keeps_output_exact(dense_models):
    """Satellite: when a selector shrinks a stream's speculation bucket, the
    blocks its earlier bigger bucket mapped become dead tail — a queued
    request's admission pressure recycles them (no stream dies) and every
    token still matches the ring run."""
    tc, tp, dc, dp = dense_models

    def selector(stream, engine):
        # big first tree, small afterwards: the first bucket maps tail
        # blocks the later frontiers do not cover
        return (2, 2, 2) if len(stream["committed"]) <= 4 else (1, 1, 1)

    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=64)
    # two short streams go big-then-small; a long third prompt queues behind
    # them (its admission needs 6 of 7 blocks) and its pressure reclaims the
    # dead tails the big first buckets left behind
    prompts = [[1, 2, 3], [7, 6, 5], list(range(1, 18))]
    seeds, max_news = [40, 41, 42], [8, 8, 4]
    ring = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, selector=selector,
                                    n_slots=3, paged=False)
    rids = [ring.submit(p, max_new=m, seed=s)
            for p, s, m in zip(prompts, seeds, max_news)]
    wout = ring.run()
    eng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, selector=selector,
                                   n_slots=3, paged=True, block_size=4,
                                   pool_blocks=7)
    rp = [eng.submit(p, max_new=m, seed=s)
          for p, s, m in zip(prompts, seeds, max_news)]
    got = eng.run()
    assert [got[r]["tokens"] for r in rp] == [wout[r]["tokens"] for r in rids]
    assert eng.counters["blocks_reclaimed"] > 0
    assert eng.counters["admit_blocked"] > 0
    assert eng.counters["evicted"] == 0


def test_lifo_pressure_eviction_under_exhaustion(dense_models):
    """When reclamation cannot cover a step's block demand, the most
    recently admitted stream is finished (reason evicted:pool_blocks) and
    the survivors continue unperturbed."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=64)
    ring = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=2, paged=False)
    first = ring.generate_batch([[1, 2, 3]], max_new=24, seeds=[50])[0]
    eng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=2,
                                   paged=True, block_size=4, pool_blocks=8)
    r0 = eng.submit([1, 2, 3], max_new=24, seed=50)
    r1 = eng.submit([4, 5, 6], max_new=24, seed=51)
    out = eng.run()
    assert out[r0]["tokens"] == first, "the older stream must be untouched"
    assert out[r0]["reason"] == "length"
    assert out[r1]["reason"] == "evicted:pool_blocks"
    assert 0 < len(out[r1]["tokens"]) < 24


def test_coresidency_beats_ring_footprint(dense_models):
    """Acceptance: 1 long + 7 short streams co-resident in an arena smaller
    than TWO ring slots — the ring design could hold at most the long
    stream alone in the same HBM."""
    tc, tp, dc, dp = dense_models
    smax, bs, pool_blocks = 64, 8, 12
    assert pool_blocks * bs < 2 * smax  # ring-equivalent capacity: 1 stream
    ecfg = EngineConfig(verifier="specinfer", K=1, L1=1, L2=1, max_cache=smax)
    eng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=8,
                                   paged=True, block_size=bs, pool_blocks=pool_blocks)
    rng = np.random.default_rng(0)
    eng.submit(rng.integers(0, V, size=12).tolist(), max_new=40, seed=60)  # long
    for i in range(7):
        eng.submit(rng.integers(0, V, size=3).tolist(), max_new=4, seed=61 + i)
    peak = 0
    while eng.queue or eng.streams:
        eng.step()
        peak = max(peak, len(eng.streams))
    assert peak == 8, f"expected 8 co-resident streams, saw {peak}"
    assert eng.counters["blocks_peak"] <= pool_blocks


# ------------------------------------------------------------ paged kernels ---


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_paged_attention_kernels_match_dense(seed):
    """Block-table kernels == dense kernels at matching KV block granularity
    (bit-identical: same online-softmax accumulation order), with the
    kernels/ref.py gather oracle providing the logical view."""
    from repro.kernels.decode_attention import decode_attention, paged_decode_attention
    from repro.kernels.ref import paged_gather_kv_ref
    from repro.kernels.tree_attention import paged_tree_attention, tree_attention

    rng = np.random.default_rng(seed)
    NB, BSZ, HKV, HDIM = 9, 8, 1, 16
    NROW, NBLK_PER = 3, 4  # logical capacity 32 slots
    ka = jnp.asarray(rng.normal(size=(NB, BSZ, HKV, HDIM)).astype(np.float32))
    va = jnp.asarray(rng.normal(size=(NB, BSZ, HKV, HDIM)).astype(np.float32))
    free = list(rng.permutation(np.arange(1, NB)))
    tbl = np.full((NROW, NBLK_PER), -1, np.int32)
    for b in range(NROW):
        for i in range(int(rng.integers(1, NBLK_PER + 1))):
            if free:
                tbl[b, i] = free.pop()
    tblj = jnp.asarray(tbl)
    S = NBLK_PER * BSZ
    kd, vd = paged_gather_kv_ref(ka, va, tblj)
    kf, vf = kd[:, :, 0], vd[:, :, 0]  # (NROW, S, HDIM): BH layout, H = 1

    T = 8
    q = jnp.asarray(rng.normal(size=(NROW, T, HDIM)).astype(np.float32))
    mapped = np.repeat(tbl >= 0, BSZ, axis=1)
    mask = np.asarray(rng.integers(0, 2, size=(NROW, T, S)), bool) & mapped[:, None, :]
    mask[:, :, 0] = mapped[:, 0:1]  # at least one admitted slot per query
    maskj = jnp.asarray(mask)
    want = tree_attention(q, kf, vf, maskj, block_k=BSZ, interpret=True)
    got = paged_tree_attention(q, ka[:, :, 0], va[:, :, 0], jnp.clip(tblj, 0),
                               maskj, interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(want))

    lens = np.asarray([int((tbl[b] >= 0).sum()) * BSZ - int(rng.integers(0, BSZ))
                       for b in range(NROW)], np.int32)
    lens = np.maximum(lens, 1)
    qd = jnp.asarray(np.broadcast_to(
        rng.normal(size=(NROW, 1, HDIM)).astype(np.float32), (NROW, 8, HDIM)))
    wantd = decode_attention(qd, kf, vf, jnp.asarray(lens)[:, None], block_k=BSZ,
                             interpret=True)
    gotd = paged_decode_attention(qd, ka[:, :, 0], va[:, :, 0], jnp.clip(tblj, 0),
                                  jnp.asarray(lens), interpret=True)
    assert np.array_equal(np.asarray(gotd), np.asarray(wantd))


def test_paged_pallas_engine_generates():
    """End-to-end: a paged engine with attention_impl=pallas routes the tree
    pass through gqa_paged_tree_attention (interpret mode) and still decodes."""
    tc = DENSE_T.replace(attention_impl="pallas", head_dim=16)
    dc = DENSE_D.replace(attention_impl="pallas", head_dim=16)
    tp = init_params(tc, jax.random.PRNGKey(0))
    dp = init_params(dc, jax.random.PRNGKey(1))
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=32)
    eng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=2,
                                   paged=True, block_size=8)
    outs = eng.generate_batch([[1, 2, 3], [4, 5]], max_new=4, seeds=[20, 21])
    assert all(len(o) == 4 for o in outs)
