"""Losslessness of whole-tree verification — the paper's central invariant.

Exact enumeration over BOTH draft-tree randomness and verifier randomness:
G(y) (the composed prefix probability, see core/enumerate.py) must match the
target process for every string, for EVERY verifier in the core/verify.py
registry, on delayed trees of several (K, L1, L2) including root rollouts,
pure paths and the K = 1 reductions.  New verifiers are covered the moment
they register — the parameterization reads the registry, not a name list.
"""
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.enumerate import (
    RandomModel,
    expected_block_dist,
    lossless_gap,
)
from repro.core.traversal import verify_traversal_output_dist
from repro.core.verify import VERIFIERS, verifier_names, verify_topdown_output_dist

# multipath verifiers also see K = 1 trees (their single-path reductions:
# univer -> BV, greedy_mpbv -> BV, specinfer -> single-draft rejection)
MULTIPATH_CASES = [(2, 0, 1), (2, 1, 1), (2, 1, 2), (1, 0, 2)]
SINGLE_CASES = [(1, 0, 2), (1, 1, 1), (1, 2, 1)]


def registry_cases():
    return [
        (name, case)
        for name in verifier_names()
        for case in (MULTIPATH_CASES if VERIFIERS[name].multipath else SINGLE_CASES)
    ]


@pytest.mark.parametrize("verifier,case", registry_cases(),
                         ids=lambda v: v if isinstance(v, str) else "x".join(map(str, v)))
def test_registry_lossless(verifier, case):
    K, L1, L2 = case
    model = RandomModel(3, seed=11, divergence=0.7)
    bd = expected_block_dist(VERIFIERS[verifier].output_dist, model, K, L1, L2)
    assert abs(sum(bd.values()) - 1.0) < 1e-12
    assert lossless_gap(bd, model, L1 + L2 + 1) < 1e-12


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.1, 1.0))
def test_traversal_lossless_hypothesis(seed, divergence):
    model = RandomModel(3, seed=seed, divergence=divergence)
    bd = expected_block_dist(verify_traversal_output_dist, model, 2, 1, 1)
    assert lossless_gap(bd, model, 3) < 1e-12


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_specinfer_lossless_with_zero_support(seed):
    model = RandomModel(3, seed=seed, divergence=0.9, zeros=True)
    bd = expected_block_dist(
        lambda t: verify_topdown_output_dist(t, "specinfer"), model, 2, 1, 1
    )
    assert lossless_gap(bd, model, 3) < 1e-12


@settings(max_examples=12, deadline=None)
@given(st.sampled_from(["univer", "greedy_mpbv"]), st.integers(0, 10_000))
def test_new_verifiers_lossless_with_zero_support(verifier, seed):
    """The PR-6 verifiers under sparse supports (warped/top-p analogues):
    zero q-mass on drafted branches and zero p-mass residuals are where
    ratio-based couplings divide by zero or leak mass."""
    model = RandomModel(3, seed=seed, divergence=0.9, zeros=True)
    bd = expected_block_dist(VERIFIERS[verifier].output_dist, model, 2, 1, 1)
    assert lossless_gap(bd, model, 3) < 1e-12


def test_traversal_beats_topdown_on_block_length():
    """Sanity: on aligned-ish models Traversal's expected block length is at
    least as large as NSS's (the paper's headline ordering at the extremes)."""
    from repro.core.enumerate import mean_block_len

    model = RandomModel(3, seed=9, divergence=0.5)
    bd_t = expected_block_dist(verify_traversal_output_dist, model, 2, 0, 2)
    bd_n = expected_block_dist(
        lambda t: verify_topdown_output_dist(t, "nss"), model, 2, 0, 2
    )
    assert mean_block_len(bd_t) >= mean_block_len(bd_n) - 1e-9
