"""Losslessness of whole-tree verification — the paper's central invariant.

Exact enumeration over BOTH draft-tree randomness and verifier randomness:
G(y) (the composed prefix probability, see core/enumerate.py) must match the
target process for every string, for every verifier, on delayed trees of
several (K, L1, L2) including root rollouts and pure paths.
"""
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.enumerate import (
    RandomModel,
    expected_block_dist,
    lossless_gap,
)
from repro.core.traversal import verify_traversal_output_dist
from repro.core.verify import verify_bv_output_dist, verify_topdown_output_dist

TOPDOWN = ["nss", "naivetree", "spectr", "specinfer", "khisti"]
CASES = [(2, 0, 1), (2, 1, 1), (3, 0, 2), (2, 1, 2)]


@pytest.mark.parametrize("solver", TOPDOWN)
@pytest.mark.parametrize("K,L1,L2", [(2, 0, 1), (2, 1, 2)])
def test_topdown_lossless(solver, K, L1, L2):
    model = RandomModel(3, seed=11, divergence=0.7)
    bd = expected_block_dist(
        lambda t: verify_topdown_output_dist(t, solver), model, K, L1, L2
    )
    assert lossless_gap(bd, model, L1 + L2 + 1) < 1e-12


@pytest.mark.parametrize("K,L1,L2", CASES + [(1, 0, 2), (1, 2, 1)])
def test_traversal_lossless(K, L1, L2):
    model = RandomModel(3, seed=5, divergence=0.8)
    bd = expected_block_dist(verify_traversal_output_dist, model, K, L1, L2)
    assert abs(sum(bd.values()) - 1.0) < 1e-12
    assert lossless_gap(bd, model, L1 + L2 + 1) < 1e-12


@pytest.mark.parametrize("L", [1, 2, 3])
def test_bv_lossless(L):
    model = RandomModel(3, seed=7, divergence=0.9)
    bd = expected_block_dist(verify_bv_output_dist, model, 1, 0, L)
    assert lossless_gap(bd, model, L + 1) < 1e-12


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.1, 1.0))
def test_traversal_lossless_hypothesis(seed, divergence):
    model = RandomModel(3, seed=seed, divergence=divergence)
    bd = expected_block_dist(verify_traversal_output_dist, model, 2, 1, 1)
    assert lossless_gap(bd, model, 3) < 1e-12


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_specinfer_lossless_with_zero_support(seed):
    model = RandomModel(3, seed=seed, divergence=0.9, zeros=True)
    bd = expected_block_dist(
        lambda t: verify_topdown_output_dist(t, "specinfer"), model, 2, 1, 1
    )
    assert lossless_gap(bd, model, 3) < 1e-12


def test_traversal_beats_topdown_on_block_length():
    """Sanity: on aligned-ish models Traversal's expected block length is at
    least as large as NSS's (the paper's headline ordering at the extremes)."""
    from repro.core.enumerate import mean_block_len

    model = RandomModel(3, seed=9, divergence=0.5)
    bd_t = expected_block_dist(verify_traversal_output_dist, model, 2, 0, 2)
    bd_n = expected_block_dist(
        lambda t: verify_topdown_output_dist(t, "nss"), model, 2, 0, 2
    )
    assert mean_block_len(bd_t) >= mean_block_len(bd_n) - 1e-9
