"""Sharding-rule unit tests (tiny mesh; the production mesh is exercised by
launch/dryrun.py which this suite does not re-run)."""
import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_smoke
from repro.launch.sharding import _spec_for, batch_shardings, param_shardings
from repro.models.transformer import init_params


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 4, "model": 8}


def test_rule_specs():
    m = FakeMesh()
    assert _spec_for("embed", (1024, 512), m) == P("model", "data")
    assert _spec_for("blocks/attn/wq", (12, 512, 1024), m) == P(None, "data", "model")
    assert _spec_for("blocks/attn/wo", (12, 1024, 512), m) == P(None, "model", "data")
    assert _spec_for("blocks/mlp/w_down", (12, 2048, 512), m) == P(None, "model", "data")
    # MoE 4D expert tensors: experts -> model
    assert _spec_for("blocks/mlp/w_gate", (12, 16, 512, 128), m) == P(None, "model", "data", None)
    assert _spec_for("blocks/ln1", (12, 512), m) == P()


def test_divisibility_guard_drops_axes():
    m = FakeMesh()
    # vocab 49155 not divisible by 8 -> replicated on that dim
    assert _spec_for("embed", (49155, 512), m) == P(None, "data")
    assert _spec_for("lm_head", (512, 49155), m) == P("data", None)
    # odd hidden: both dropped
    assert _spec_for("blocks/attn/wq", (2, 511, 1023), m) == P(None, None, None)


def test_param_shardings_cover_tree():
    dev = jax.devices()[0]
    mesh = Mesh(np.asarray([[dev]]), ("data", "model"))
    cfg = get_smoke("qwen3-moe-235b-a22b")
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    sh = param_shardings(mesh, shapes)
    n_params = len(jax.tree.leaves(shapes))
    n_sh = len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
    assert n_params == n_sh


def test_batch_shardings_guard():
    dev = jax.devices()[0]
    mesh = Mesh(np.asarray([[dev]]), ("data", "model"))
    batch = {
        "tokens": jax.ShapeDtypeStruct((8, 16), np.int32),
        "labels": jax.ShapeDtypeStruct((8, 16), np.int32),
    }
    sh = batch_shardings(mesh, batch)
    assert all(hasattr(s, "spec") for s in jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes

    hlo = """
  %ag = bf16[2048,4096]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[128]{0} all-reduce(%y), to_apply=%sum
  %fused = f32[16]{0} fusion(%z), kind=kLoop
  %a2a = bf16[64,32]{1,0} all-to-all(%w)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 2048 * 4096 * 2
    assert out["all-reduce"] == 128 * 4
    assert out["all-to-all"] == 64 * 32 * 2
    assert out["reduce-scatter"] == 0
