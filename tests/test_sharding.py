"""Sharding-rule unit tests (tiny mesh; the production mesh is exercised by
launch/dryrun.py which this suite does not re-run), plus the pool-sharding
property suite: the sharded continuous-batching engine must emit
token-identical output to the unsharded pool for the same arrival order
(both strategies x both verifiers, synchronous and pipelined, including a
capacity-eviction-under-pressure scenario), and its admission/eviction
decisions must be shard-local."""
import logging

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_smoke
from repro.launch import sharding as sharding_mod
from repro.launch.mesh import shard_meshes
from repro.launch.sharding import (
    _spec_for,
    batch_shardings,
    pad_slots,
    param_shardings,
    pool_specs,
)
from repro.models.config import ModelConfig
from repro.models.transformer import init_cache, init_params
from repro.serving.batch_engine import (
    BatchedSpeculativeEngine,
    ShardedBatchedSpeculativeEngine,
)
from repro.serving.engine import EngineConfig, SpeculativeEngine

V = 32

DENSE_T = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")
DENSE_D = ModelConfig(name="d", arch_type="dense", n_layers=1, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")
SSM_CFG = ModelConfig(name="s", arch_type="ssm", n_layers=2, d_model=48, vocab=V,
                      ssm_state=16, ssm_headdim=16, ssm_chunk=8, dtype="float32")

PROMPTS = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [3, 1]]
SEEDS = [20, 21, 22, 23]


@pytest.fixture(scope="module")
def dense_models():
    return (DENSE_T, init_params(DENSE_T, jax.random.PRNGKey(0)),
            DENSE_D, init_params(DENSE_D, jax.random.PRNGKey(1)))


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 4, "model": 8}


def test_rule_specs():
    m = FakeMesh()
    assert _spec_for("embed", (1024, 512), m) == P("model", "data")
    assert _spec_for("blocks/attn/wq", (12, 512, 1024), m) == P(None, "data", "model")
    assert _spec_for("blocks/attn/wo", (12, 1024, 512), m) == P(None, "model", "data")
    assert _spec_for("blocks/mlp/w_down", (12, 2048, 512), m) == P(None, "model", "data")
    # MoE 4D expert tensors: experts -> model
    assert _spec_for("blocks/mlp/w_gate", (12, 16, 512, 128), m) == P(None, "model", "data", None)
    assert _spec_for("blocks/ln1", (12, 512), m) == P()


def test_divisibility_guard_drops_axes():
    m = FakeMesh()
    # vocab 49155 not divisible by 8 -> replicated on that dim
    assert _spec_for("embed", (49155, 512), m) == P(None, "data")
    assert _spec_for("lm_head", (512, 49155), m) == P("data", None)
    # odd hidden: both dropped
    assert _spec_for("blocks/attn/wq", (2, 511, 1023), m) == P(None, None, None)


def test_param_shardings_cover_tree():
    dev = jax.devices()[0]
    mesh = Mesh(np.asarray([[dev]]), ("data", "model"))
    cfg = get_smoke("qwen3-moe-235b-a22b")
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    sh = param_shardings(mesh, shapes)
    n_params = len(jax.tree.leaves(shapes))
    n_sh = len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
    assert n_params == n_sh


def test_batch_shardings_guard():
    dev = jax.devices()[0]
    mesh = Mesh(np.asarray([[dev]]), ("data", "model"))
    batch = {
        "tokens": jax.ShapeDtypeStruct((8, 16), np.int32),
        "labels": jax.ShapeDtypeStruct((8, 16), np.int32),
    }
    sh = batch_shardings(mesh, batch)
    assert all(hasattr(s, "spec") for s in jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))


def test_divisibility_drop_logs_once(caplog):
    """Silently replicating half the model is the bug class the guard log
    exists for: the drop must be reported, but only once per param class."""
    sharding_mod._logged_drops.clear()
    m = FakeMesh()
    with caplog.at_level(logging.WARNING, logger="repro.launch.sharding"):
        assert _spec_for("embed", (49155, 512), m) == P(None, "data")
        assert _spec_for("embed", (49155, 512), m) == P(None, "data")
    drops = [r for r in caplog.records if "drops axis" in r.getMessage()]
    assert len(drops) == 1, [r.getMessage() for r in caplog.records]


# ------------------------------------------------------- pool stream axis ---


def test_pool_specs_stream_axis():
    ring = init_cache(DENSE_T, 8, 32, per_stream=True)
    sp = pool_specs({"data": 4, "model": 2}, ring)
    assert sp["attn"]["k"] == P(None, "data", None, None, None)
    assert sp["attn"]["pos"] == P("data", None)
    assert sp["attn"]["len"] == P("data")

    paged = init_cache(DENSE_T, 8, 32, per_stream=True, page=(8, 8))
    sp = pool_specs({"data": 4}, paged)
    # the arena has no stream axis (and an odd trash block): replicated —
    # the sharded engine gives each shard a private arena instead
    assert sp["attn"]["k"] == P()
    assert sp["attn"]["block_tbl"] == P("data", None)
    assert sp["attn"]["pos"] == P("data", None)

    ssm = init_cache(SSM_CFG, 8, 32, per_stream=True)
    sp = pool_specs({"data": 2}, ssm)
    assert sp["state"] == P(None, "data", None, None, None)
    assert sp["conv"] == P(None, "data", None, None)
    assert sp["len"] == P("data")


def test_pool_stream_axis_must_divide():
    """Unlike param rules the stream axis never silently drops: pad n_slots
    up instead of replicating a pool shard."""
    ring = init_cache(DENSE_T, 3, 32, per_stream=True)
    with pytest.raises(AssertionError, match="pad n_slots"):
        pool_specs({"data": 2}, ring)
    assert pad_slots(3, 2) == 4
    assert pad_slots(4, 2) == 4
    assert pad_slots(1, 4) == 4
    assert pad_slots(5, 1) == 5


def test_sharded_pools_carry_named_shardings(dense_models):
    """Every shard's pool arrays are committed to its mesh slice: the
    stream axis carries a NamedSharding over the shard's data axis."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=64)
    eng = ShardedBatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4,
                                          data_shards=2)
    assert [sh.n_slots for sh in eng.shards] == [2, 2]
    for sh in eng.shards:
        tbl = sh.tpool.cache["attn"]["block_tbl"]
        assert isinstance(tbl.sharding, NamedSharding)
        assert tuple(tbl.sharding.spec) == ("data", None)
        assert "data" in tbl.sharding.mesh.axis_names
    # n_slots pads UP to a shard multiple rather than replicating a shard
    odd = ShardedBatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=3,
                                          data_shards=2)
    assert odd.n_slots == 4 and [sh.n_slots for sh in odd.shards] == [2, 2]
    assert len(shard_meshes(3)) == 3


# -------------------------------------- sharded == unsharded token identity ---


@pytest.mark.parametrize("pipeline", [False, True], ids=["sync", "pipelined"])
@pytest.mark.parametrize("verifier", ["specinfer", "traversal", "univer", "greedy_mpbv"])
def test_sharded_matches_unsharded_tree(dense_models, verifier, pipeline):
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier=verifier, K=2, L1=1, L2=1, max_cache=128)
    base = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4,
                                    pipeline=pipeline)
    ref = base.generate_batch(PROMPTS, max_new=12, seeds=SEEDS)
    eng = ShardedBatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4,
                                          data_shards=2, pipeline=pipeline)
    assert eng.strategy == "tree"
    assert eng.generate_batch(PROMPTS, max_new=12, seeds=SEEDS) == ref
    # the scheduler spread the four streams across both shards
    assert all(sh.counters["blocks"] > 0 for sh in eng.shards)


@pytest.mark.slow
@pytest.mark.parametrize("pipeline", [False, True], ids=["sync", "pipelined"])
@pytest.mark.parametrize("verifier", ["specinfer", "traversal", "univer", "greedy_mpbv"])
def test_sharded_matches_unsharded_replay(verifier, pipeline):
    params = init_params(SSM_CFG, jax.random.PRNGKey(0))
    ecfg = EngineConfig(verifier=verifier, K=2, L1=1, L2=1, max_cache=128)
    base = BatchedSpeculativeEngine(SSM_CFG, params, SSM_CFG, params, ecfg,
                                    n_slots=4, pipeline=pipeline)
    ref = base.generate_batch(PROMPTS, max_new=8, seeds=SEEDS)
    eng = ShardedBatchedSpeculativeEngine(SSM_CFG, params, SSM_CFG, params, ecfg,
                                          n_slots=4, data_shards=2,
                                          pipeline=pipeline)
    assert eng.strategy == "replay"
    assert eng.generate_batch(PROMPTS, max_new=8, seeds=SEEDS) == ref


@pytest.mark.slow
def test_sharded_continuous_admission_exact(dense_models):
    """More requests than total slots: per-shard FIFOs admit as their own
    rows free up, and outputs still match the unsharded pool (admission
    *timing* may differ across schedulers; tokens may not)."""
    tc, tp, dc, dp = dense_models
    prompts = [[i + 1, i + 2] for i in range(6)]
    max_news = [6, 14, 10, 8, 12, 9]
    seeds = [30 + i for i in range(6)]
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    base = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4)
    ref = {}
    for p, sd, mn in zip(prompts, seeds, max_news):
        ref[base.submit(p, max_new=mn, seed=sd)] = None
    outs = base.run()
    ref = [outs[r]["tokens"] for r in sorted(outs)]
    eng = ShardedBatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4,
                                          data_shards=2, pipeline=True)
    rids = [eng.submit(p, max_new=mn, seed=sd)
            for p, sd, mn in zip(prompts, seeds, max_news)]
    sout = eng.run()
    assert [sout[r]["tokens"] for r in rids] == ref
    # fully drained: every shard's rows are free again
    assert all(sh.tpool.free_slots == sh.n_slots for sh in eng.shards)


def test_sharded_eviction_identity(dense_models):
    """Capacity eviction under pressure fires at the SAME step in both
    engines: with a homogeneous action the eviction bound C-1+Tpad is a
    pure per-stream condition (Dp <= Tpad for (2,1,1)), so shard-local
    vs global shape bucketing cannot shift it — tokens AND truncation
    reasons are identical."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=24)
    prompts, seeds = [[1, 2, 3], [4, 5]], [7, 9]
    base = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=2)
    brids = [base.submit(p, max_new=64, seed=sd) for p, sd in zip(prompts, seeds)]
    bouts = base.run()
    assert all(bouts[r]["reason"].startswith("evicted") for r in brids)
    eng = ShardedBatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=2,
                                          data_shards=2)
    srids = [eng.submit(p, max_new=64, seed=sd) for p, sd in zip(prompts, seeds)]
    assert [eng.shard_of(r) for r in srids] == [0, 1]
    souts = eng.run()
    assert [souts[r] for r in srids] == [bouts[r] for r in brids]
    assert sum(sh.counters["evicted"] for sh in eng.shards) == 2


# ------------------------------------------------------ shard-local decisions ---


def test_pressure_eviction_is_shard_local(dense_models):
    """Block pressure in one shard evicts from THAT shard's streams only
    (LIFO within the shard); the other shard's streams are untouched and
    emit exactly their independent single-engine output."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=64)
    eng = ShardedBatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4,
                                          data_shards=2, block_size=16,
                                          pool_blocks=10)  # 5 per shard < 2 rings
    # routing (least-loaded, ties to shard 0): A->0, B->1, C->0, D->1
    rid_a = eng.submit([1, 2, 3], max_new=64, seed=40)
    rid_b = eng.submit([4, 5], max_new=4, seed=41)
    rid_c = eng.submit([6, 7], max_new=64, seed=42)
    rid_d = eng.submit([8, 9], max_new=4, seed=43)
    assert [eng.shard_of(r) for r in (rid_a, rid_b, rid_c, rid_d)] == [0, 1, 0, 1]
    outs = eng.run()
    # shard 0 hit block pressure: its LATEST stream (C) was the LIFO victim,
    # and the survivor (A) later hit its ring capacity
    assert outs[rid_c]["reason"] == "evicted:pool_blocks"
    assert outs[rid_a]["reason"].startswith("evicted")
    assert eng.shards[0].counters["evicted"] == 2
    # shard 1 never felt shard 0's pressure
    assert eng.shards[1].counters["evicted"] == 0
    assert eng.shards[1].counters["blocks_reclaimed"] == 0
    for rid, prompt, seed in ((rid_b, [4, 5], 41), (rid_d, [8, 9], 43)):
        single = SpeculativeEngine(
            tc, tp, dc, dp,
            EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=64,
                         seed=seed))
        assert outs[rid]["tokens"] == single.generate(prompt, max_new=4)


def test_admission_routes_around_exhausted_shard(dense_models):
    """One shard's block free list is exhausted while the other has blocks:
    the scheduler routes the new request to the shard that can admit it,
    instead of queueing it behind an arena it does not need."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=64)
    eng = ShardedBatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4,
                                          data_shards=2, block_size=16,
                                          pool_blocks=8)  # 4 per shard
    long_prompt = [(i % (V - 2)) + 1 for i in range(44)]
    rid_a = eng.submit(long_prompt, max_new=8, seed=50)
    assert eng.shard_of(rid_a) == 0
    eng.step()  # admit A: its context maps 3 of shard 0's 4 blocks
    s0 = eng.shards[0]
    assert s0.tpool.free_slots > 0, "exhaustion must come from blocks, not rows"
    assert all(p.free_blocks < 2 for p in s0._paged_pools())
    rid_b = eng.submit([3, 1, 4, 1] * 5, max_new=4, seed=51)  # needs 2 blocks
    assert eng.shard_of(rid_b) == 1, "scheduler must route around the dry shard"
    outs = eng.run()
    assert len(outs[rid_b]["tokens"]) == 4
    # shard 0 never queued the request it could not serve
    assert s0.counters["admit_blocked"] == 0


def test_multi_shard_abort_rewinds_all(dense_models):
    """``abort_pipeline`` with SEVERAL shards begun-ahead must rewind every
    one of them: each shard restores its own rng snapshots and pool writes,
    so the continued run still emits the synchronous sharded token stream.
    (A partial rewind would replay one shard's randomness against another's
    already-consumed state — the regression this pins down.)"""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    base = ShardedBatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4,
                                           data_shards=2)
    want = base.generate_batch(PROMPTS, max_new=12, seeds=SEEDS)
    eng = ShardedBatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4,
                                          data_shards=2, pipeline=True)
    rids = [eng.submit(list(p), max_new=12, seed=sd)
            for p, sd in zip(PROMPTS, SEEDS)]
    eng.step()  # steady state: BOTH shards leave a step begun-ahead
    assert sum(sh._pending_next is not None for sh in eng.shards) == 2
    assert eng.abort_pipeline() == 2
    assert all(sh._pending_next is None for sh in eng.shards)
    assert not any(sh.dpool.frame_held for sh in eng.shards)
    assert eng.abort_pipeline() == 0  # idempotent once quiescent
    outs = eng.run()
    assert [outs[r]["tokens"] for r in rids] == want


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes

    hlo = """
  %ag = bf16[2048,4096]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[128]{0} all-reduce(%y), to_apply=%sum
  %fused = f32[16]{0} fusion(%z), kind=kLoop
  %a2a = bf16[64,32]{1,0} all-to-all(%w)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 2048 * 4096 * 2
    assert out["all-reduce"] == 128 * 4
    assert out["all-to-all"] == 64 * 32 * 2
    assert out["reduce-scatter"] == 0


def test_bin_packing_groups_similar_actions(dense_models):
    """Selector-aware routing: alternating big/thin action hints land
    big-with-big and thin-with-thin, so each shard's pool-wide speculation
    bucket stays tight instead of every shard stepping at the big Tpad."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    eng = ShardedBatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4,
                                          data_shards=2)
    big, thin = (4, 2, 4), (1, 1, 0)
    hints = [big, thin, big, thin]
    rids = [eng.submit(list(p), max_new=4, seed=sd, action_hint=h)
            for p, sd, h in zip(PROMPTS, SEEDS, hints)]
    shards = [eng.shard_of(r) for r in rids]
    assert shards[0] == shards[2], "both big-bucket streams must co-reside"
    assert shards[1] == shards[3], "both thin-bucket streams must co-reside"
    assert shards[0] != shards[1], "big and thin buckets must not mix"
    outs = eng.run()
    assert all(len(outs[r]["tokens"]) == 4 for r in rids)


def test_bin_packing_deterministic_and_output_invariant(dense_models):
    """The schedule is a pure function of arrival order and hints: two
    identical engines place identically and emit identical tokens — and the
    hints steer PLACEMENT only, so a hint-free engine serving the same
    arrivals emits the same per-request tokens from (possibly) different
    shards."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    hints = [(4, 2, 4), (1, 1, 0), (1, 1, 0), (4, 2, 4)]

    def serve(with_hints):
        eng = ShardedBatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4,
                                              data_shards=2)
        rids = [eng.submit(list(p), max_new=8, seed=sd,
                           action_hint=(h if with_hints else None))
                for p, sd, h in zip(PROMPTS, SEEDS, hints)]
        placed = [eng.shard_of(r) for r in rids]
        outs = eng.run()
        return placed, [outs[r]["tokens"] for r in rids]

    placed_a, outs_a = serve(True)
    placed_b, outs_b = serve(True)
    assert placed_a == placed_b, "same arrivals + hints must place identically"
    assert outs_a == outs_b
    # heterogeneous hints produced a non-least-loaded grouping…
    assert placed_a == [0, 1, 1, 0]
    placed_free, outs_free = serve(False)
    # …while hint-free routing stays the original least-loaded round-robin
    assert placed_free == [0, 1, 0, 1]
    assert outs_free == outs_a, "hints must never change emitted tokens"


def test_bin_packing_homogeneous_hints_degrade_to_least_loaded(dense_models):
    """With every hint in the same bucket all pack costs are 0 and routing
    is EXACTLY the original least-loaded rule (the pinned placements above
    this suite rely on that degradation)."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    eng = ShardedBatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4,
                                          data_shards=2)
    rids = [eng.submit(list(p), max_new=4, seed=sd, action_hint=(2, 1, 1))
            for p, sd in zip(PROMPTS, SEEDS)]
    assert [eng.shard_of(r) for r in rids] == [0, 1, 0, 1]
    eng.run()
