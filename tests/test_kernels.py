"""Pallas kernel validation (interpret mode) against the pure-jnp oracles:
shape/dtype sweeps with assert_allclose, plus seeded property checks
(the vendored _propcheck shim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.kernels.ops import gqa_decode_attention, gqa_tree_attention
from repro.kernels.ref import decode_attention_ref, tree_attention_ref


def _mk(key, B, T, H, Hkv, D, S, dtype):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, T, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    mask = jax.random.bernoulli(ks[3], 0.5, (B, T, S)).at[:, :, 0].set(True)
    return q, k, v, mask


def _ref_tree(q, k, v, mask):
    B, T, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    kr = jnp.repeat(k.transpose(0, 2, 1, 3), G, 1).reshape(B * H, S, D)
    vr = jnp.repeat(v.transpose(0, 2, 1, 3), G, 1).reshape(B * H, S, D)
    mr = jnp.broadcast_to(mask[:, None], (B, H, T, S)).reshape(B * H, T, S)
    return tree_attention_ref(qr, kr, vr, mr).reshape(B, H, T, D).transpose(0, 2, 1, 3)


@pytest.mark.slow
@pytest.mark.parametrize("T", [1, 5, 8, 17])
@pytest.mark.parametrize("S,block_k", [(64, 128), (96, 128), (256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tree_attention_sweep(T, S, block_k, dtype):
    q, k, v, mask = _mk(jax.random.PRNGKey(hash((T, S)) % 2**31), 2, T, 4, 2, 128, S, dtype)
    out = gqa_tree_attention(q, k, v, mask, block_k=block_k, interpret=True)
    ref = _ref_tree(q, k, v, mask)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("H,Hkv", [(4, 4), (8, 2), (4, 1)])
def test_tree_attention_gqa_groups(H, Hkv):
    q, k, v, mask = _mk(jax.random.PRNGKey(0), 1, 6, H, Hkv, 128, 128, jnp.float32)
    out = gqa_tree_attention(q, k, v, mask, block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_ref_tree(q, k, v, mask)), atol=2e-5)


@pytest.mark.slow
@pytest.mark.parametrize("S,lengths", [(128, (7, 128)), (256, (250, 1))])
@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(S, lengths, window, dtype):
    B, H, Hkv, D = 2, 4, 2, 128
    key = jax.random.PRNGKey(hash((S, lengths, window)) % 2**31)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    ln = jnp.asarray(lengths, jnp.int32)
    out = gqa_decode_attention(q, k, v, ln, block_k=128, window=window, interpret=True)
    G = H // Hkv
    qr = jnp.broadcast_to(q.transpose(0, 2, 1, 3), (B, H, 1, D)).reshape(B * H, 1, D)
    kr = jnp.repeat(k.transpose(0, 2, 1, 3), G, 1).reshape(B * H, S, D)
    vr = jnp.repeat(v.transpose(0, 2, 1, 3), G, 1).reshape(B * H, S, D)
    lr = jnp.broadcast_to(ln[:, None], (B, H)).reshape(B * H, 1)
    ref = decode_attention_ref(qr, kr, vr, lr, window=window)
    ref = ref.reshape(B, H, 1, D).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(st.integers(1, 10), st.integers(1, 200), st.integers(0, 2**31 - 1))
def test_tree_attention_property(T, S, seed):
    """Arbitrary (T, S): kernel == oracle after the wrapper's padding."""
    q, k, v, mask = _mk(jax.random.PRNGKey(seed), 1, T, 2, 1, 128, S, jnp.float32)
    out = gqa_tree_attention(q, k, v, mask, block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_ref_tree(q, k, v, mask)), atol=3e-5)


def test_tree_attention_equals_engine_attention():
    """The kernel must agree with the model's jnp gqa_attend on a tree mask."""
    from repro.models.layers import gqa_attend

    q, k, v, mask = _mk(jax.random.PRNGKey(5), 2, 7, 4, 2, 128, 64, jnp.float32)
    out_k = gqa_tree_attention(q, k, v, mask, block_k=128, interpret=True)
    out_m = gqa_attend(q, k, v, mask[:, None])
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_m), atol=3e-5)


def test_pallas_attention_impl_in_model():
    """cfg.attention_impl='pallas' must reproduce the XLA path end-to-end
    (full pass and cached decode)."""
    import numpy as np
    from repro.models.config import ModelConfig
    from repro.models.transformer import forward, init_cache, init_params

    cfg = ModelConfig(name="t", n_layers=2, d_model=256, n_heads=2, n_kv_heads=1,
                      d_ff=256, vocab=64, dtype="float32", head_dim=128)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 64, (2, 8)), jnp.int32)
    lg_x, _, _ = forward(params, cfg, toks, mode="full")
    lg_p, _, _ = forward(params, cfg.replace(attention_impl="pallas"), toks, mode="full")
    np.testing.assert_allclose(np.asarray(lg_x), np.asarray(lg_p), atol=1e-4)

    c1 = init_cache(cfg, 2, 32)
    _, c1, _ = forward(params, cfg, toks, mode="full", cache=c1)
    d1, _, _ = forward(params, cfg, toks[:, :1], mode="decode", cache=c1)
    cfg_p = cfg.replace(attention_impl="pallas")
    c2 = init_cache(cfg_p, 2, 32)
    _, c2, _ = forward(params, cfg_p, toks, mode="full", cache=c2)
    d2, _, _ = forward(params, cfg_p, toks[:, :1], mode="decode", cache=c2)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-4)


def _mk_ragged(seed, segs, H=4, Hkv=2, D=128, nb=4, block=16, tail=0):
    """A ragged node-major attention problem: ``segs`` 8-row Q tiles per
    stream (``tail`` trims rows off the last stream's final tile, exercising
    the wrapper's pad-and-slice), a paged arena with per-stream block
    tables (-1 = unmapped; unmapped logical slots masked False)."""
    import numpy as np
    from repro.kernels.ops import gqa_ragged_tree_attention  # noqa: F401

    B = len(segs)
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    N = 8 * sum(segs) - tail
    owner = np.repeat(np.arange(B, dtype=np.int32), [8 * s for s in segs])[:N]
    NBLK = 1 + B * nb  # block 0 is the trash block unmapped entries clamp to
    k_arena = jax.random.normal(ks[0], (NBLK, block, Hkv, D), jnp.float32)
    v_arena = jax.random.normal(ks[1], (NBLK, block, Hkv, D), jnp.float32)
    rng = np.random.default_rng(seed)
    tbl = np.full((B, nb), -1, np.int32)
    perm = rng.permutation(np.arange(1, NBLK, dtype=np.int32))
    taken = 0
    for b in range(B):
        nmap = int(rng.integers(1, nb + 1))
        tbl[b, :nmap] = perm[taken:taken + nmap]
        taken += nmap
    q = jax.random.normal(ks[2], (N, H, D), jnp.float32)
    mask = np.array(jax.random.bernoulli(ks[3], 0.5, (N, nb * block)))
    mask &= np.repeat(tbl >= 0, block, axis=1)[owner]  # unmapped slots False
    mask[:, 0] = True  # slot 0 is always mapped (tbl[:, 0] >= 0 above)
    return (q, k_arena, v_arena, jnp.asarray(tbl), jnp.asarray(owner),
            jnp.asarray(mask))


def test_ragged_tree_attention_matches_oracle():
    """The scalar-prefetched owner steering reads each tile's OWN stream's
    arena blocks: kernel == pure-jnp gather oracle across a 3-stream ragged
    buffer with distinct per-stream block tables."""
    from repro.kernels.ops import gqa_ragged_tree_attention
    from repro.kernels.ref import ragged_tree_attention_ref

    args = _mk_ragged(0, segs=[1, 2, 1])
    out = gqa_ragged_tree_attention(*args, interpret=True)
    ref = ragged_tree_attention_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_ragged_tree_attention_partial_tail_tile():
    """N not a multiple of 8: the wrapper pads with all-False mask rows and
    slices them back off; the padded tail must not perturb real rows."""
    from repro.kernels.ops import gqa_ragged_tree_attention
    from repro.kernels.ref import ragged_tree_attention_ref

    args = _mk_ragged(1, segs=[1, 1, 2], tail=5)
    assert args[0].shape[0] % 8 != 0
    out = gqa_ragged_tree_attention(*args, interpret=True)
    ref = ragged_tree_attention_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(st.integers(1, 4), st.integers(0, 7), st.integers(0, 2**31 - 1))
def test_ragged_tree_attention_property(n_streams, tail, seed):
    """Arbitrary stream counts, segment lengths, ragged tails and sparse
    block tables: kernel == oracle."""
    from repro.kernels.ops import gqa_ragged_tree_attention
    from repro.kernels.ref import ragged_tree_attention_ref

    segs = np.random.default_rng(seed).integers(1, 4, size=n_streams).tolist()
    tail = min(tail, 8 * segs[-1] - 1)
    args = _mk_ragged(seed, segs=segs, H=2, Hkv=1, nb=3, tail=tail)
    out = gqa_ragged_tree_attention(*args, interpret=True)
    ref = ragged_tree_attention_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
