"""Race/interleaving stress harness for concurrent shard stepping.

The phased sharded step dispatches every shard's work up front and then
verifies the shards in ``_finish_order`` — on real multi-device hosts the
shards' device work completes in ANY order, so the host-side phases must be
order-insensitive.  This harness makes that nondeterminism deterministic:
a seeded scheduler shuffle permutes the verify order every iteration while
mid-run ``submit()`` calls, staggered retirements, and capacity evictions
land between steps.  Across 20+ permutation rounds the token stream must
stay identical to a synchronous sharded oracle given the same call trace,
and the overlap counters must keep their defining invariant

    pipeline_ahead + pipeline_stalls == pipeline_iterations

on every shard (each pipeline-ahead decision either begins a step or
records an empty boundary — nothing is dropped, double-counted, or leaked
across rounds).
"""
import jax
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.transformer import init_params
from repro.serving.batch_engine import ShardedBatchedSpeculativeEngine
from repro.serving.engine import EngineConfig

V = 32

DENSE_T = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")
DENSE_D = ModelConfig(name="d", arch_type="dense", n_layers=1, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")

ROUNDS = 21  # 3 scenarios x 7 seeded permutations each


class ShuffledShardedEngine(ShardedBatchedSpeculativeEngine):
    """Sharded engine whose verify order is a seeded random permutation —
    the deterministic stand-in for 'whichever shard's device finished
    first'."""

    def init_shuffle(self, seed):
        self.order_rng = np.random.default_rng(seed)
        self.orders_seen = set()

    def _finish_order(self, sis):
        order = list(sis)
        self.order_rng.shuffle(order)
        self.orders_seen.add(tuple(order))
        return order


@pytest.fixture(scope="module")
def engines():
    tp = init_params(DENSE_T, jax.random.PRNGKey(0))
    dp = init_params(DENSE_D, jax.random.PRNGKey(1))
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=32)
    eng = ShuffledShardedEngine(DENSE_T, tp, DENSE_D, dp, ecfg, n_slots=4,
                                data_shards=2, pipeline=True)
    eng.init_shuffle(1234)
    oracle = ShardedBatchedSpeculativeEngine(DENSE_T, tp, DENSE_D, dp, ecfg,
                                             n_slots=4, data_shards=2)
    return eng, oracle


def _trace(eng, scenario, rnd):
    """One round's call trace, identical for the shuffled engine and the
    oracle: staggered max_new values retire streams mid-run; 'midsubmit'
    lands two submits between steps of a running engine; 'evict' drives
    two streams into ring-capacity eviction."""
    base = 100 + 10 * rnd
    if scenario == "evict":
        rids = [eng.submit([1, 2, 3], max_new=64, seed=base),
                eng.submit([4, 5], max_new=64, seed=base + 1)]
    elif scenario == "midsubmit":
        rids = [eng.submit([1, 2, 3], max_new=10, seed=base),
                eng.submit([4, 5], max_new=6, seed=base + 1)]
        eng.step()
        eng.step()
        rids += [eng.submit([6, 7, 8], max_new=8, seed=base + 2),
                 eng.submit([2, 1], max_new=12, seed=base + 3)]
    else:
        rids = [eng.submit(p, max_new=mn, seed=base + i)
                for i, (p, mn) in enumerate(
                    zip([[1, 2, 3], [4, 5], [6, 7, 8], [2, 1]],
                        [6, 14, 10, 8]))]
    outs = eng.run()
    return [(outs[r]["tokens"], outs[r]["reason"]) for r in rids]


def test_shuffled_finish_order_keeps_identity_and_counters(engines):
    eng, oracle = engines
    saw_eviction = False
    for rnd in range(ROUNDS):
        scenario = ("plain", "midsubmit", "evict")[rnd % 3]
        eng.reset_counters(("pipeline_ahead", "pipeline_stalls",
                            "pipeline_iterations"))
        got = _trace(eng, scenario, rnd)
        want = _trace(oracle, scenario, rnd)
        assert got == want, (rnd, scenario)
        for sh in eng.shards:
            c = sh.counters
            assert c["pipeline_ahead"] + c["pipeline_stalls"] \
                == c["pipeline_iterations"], (rnd, scenario, dict(c))
        # drained between rounds: no pending step or leaked rows survives
        assert all(sh._pending_next is None for sh in eng.shards)
        assert all(sh.tpool.free_slots == sh.n_slots for sh in eng.shards)
        saw_eviction |= any(r.startswith("evicted") for _, r in got)
    assert saw_eviction, "no round exercised the eviction path"
    # the shuffle really permuted: both 2-shard verify orders occurred
    assert {(0, 1), (1, 0)} <= eng.orders_seen