"""On-device (jnp) OTLP solvers and whole-tree verification vs the numpy
oracles: Monte-Carlo distribution agreement + jit/vmap compilability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.enumerate import RandomModel
from repro.core.otlp import OTLP_SOLVERS
from repro.core.otlp_jax import SOLVERS_JAX, verify_topdown_batched, verify_topdown_jax
from repro.core.trees import attach_target, build_delayed_tree
from repro.core.verify import verify_topdown_output_dist

V = 6


def _pq(seed):
    rng = np.random.default_rng(seed)
    p = rng.dirichlet(np.ones(V))
    q = rng.dirichlet(np.ones(V))
    return p, q


@pytest.mark.parametrize("solver", ["nss", "naive", "spectr", "specinfer", "khisti"])
def test_jax_solver_matches_oracle_distribution(solver):
    p, q = _pq(3)
    xs = np.asarray([1, 4], np.int32)
    _, output_dist, _ = OTLP_SOLVERS[solver]
    want = output_dist(p, q, list(xs))
    fn = jax.jit(lambda k: SOLVERS_JAX[solver](
        jnp.asarray(p, jnp.float32), jnp.asarray(q, jnp.float32),
        jnp.asarray(xs), jnp.ones(2, bool), k))
    n = 4000
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    ys = np.asarray(jax.vmap(fn)(keys))
    freq = np.bincount(ys, minlength=V) / n
    np.testing.assert_allclose(freq, want, atol=0.04)


@pytest.mark.parametrize("solver", ["spectr", "specinfer", "khisti"])
def test_jax_solver_respects_valid_mask(solver):
    """Padded (invalid) slots must behave exactly like a smaller k."""
    p, q = _pq(7)
    _, output_dist, _ = OTLP_SOLVERS[solver]
    want = output_dist(p, q, [2])  # k=1
    xs = np.asarray([2, 0, 0, 0], np.int32)  # 3 padded slots
    valid = jnp.asarray([True, False, False, False])
    fn = jax.jit(lambda k: SOLVERS_JAX[solver](
        jnp.asarray(p, jnp.float32), jnp.asarray(q, jnp.float32), jnp.asarray(xs), valid, k))
    n = 4000
    ys = np.asarray(jax.vmap(fn)(jax.random.split(jax.random.PRNGKey(1), n)))
    freq = np.bincount(ys, minlength=V) / n
    np.testing.assert_allclose(freq, want, atol=0.04)


def _tree_arrays(tree, max_nodes):
    N = tree.n_nodes
    tokens = np.full(max_nodes, -1, np.int32)
    parent = np.full(max_nodes, -1, np.int32)
    tokens[:N] = tree.tokens
    parent[:N] = tree.parent
    p = np.zeros((max_nodes, tree.vocab), np.float32)
    q = np.zeros((max_nodes, tree.vocab), np.float32)
    p[:N] = tree.p
    q[:N] = tree.q
    return tokens, parent, p, q


@pytest.mark.parametrize("solver", ["specinfer", "spectr", "naivetree"])
def test_jax_tree_verify_matches_host_block_distribution(solver):
    model = RandomModel(4, seed=5, divergence=0.6)
    rng = np.random.default_rng(0)
    tree = attach_target(build_delayed_tree(rng, model.q, 2, 1, 1), model.p)
    want = verify_topdown_output_dist(tree, solver)  # exact conditional law
    tokens, parent, p, q = _tree_arrays(tree, 8)
    n = 5000
    keys = jax.random.split(jax.random.PRNGKey(2), n)
    out_tok, n_acc, corr = jax.vmap(
        lambda k: verify_topdown_jax(
            jnp.asarray(tokens), jnp.asarray(parent), jnp.asarray(p), jnp.asarray(q), k,
            solver=solver, max_depth=4, max_children=4,
        )
    )(keys)
    out_tok = np.asarray(out_tok)
    n_acc = np.asarray(n_acc)
    corr = np.asarray(corr)
    got: dict = {}
    for i in range(n):
        blk = tuple(out_tok[i, : n_acc[i]].tolist()) + (int(corr[i]),)
        got[blk] = got.get(blk, 0) + 1.0 / n
    keys_all = set(want) | set(got)
    worst = max(abs(want.get(k, 0) - got.get(k, 0)) for k in keys_all)
    assert worst < 0.05, worst


def test_jax_tree_verify_batched_shapes():
    model = RandomModel(4, seed=9, divergence=0.5)
    rng = np.random.default_rng(1)
    B = 3
    toks, pars, ps, qs, keys = [], [], [], [], []
    for b in range(B):
        tree = attach_target(build_delayed_tree(rng, model.q, 2, 1, 1), model.p)
        t, par, p, q = _tree_arrays(tree, 8)
        toks.append(t)
        pars.append(par)
        ps.append(p)
        qs.append(q)
    out_tok, n_acc, corr = verify_topdown_batched(
        jnp.asarray(np.stack(toks)), jnp.asarray(np.stack(pars)),
        jnp.asarray(np.stack(ps)), jnp.asarray(np.stack(qs)),
        jax.random.split(jax.random.PRNGKey(3), B),
        solver="specinfer", max_depth=4,
    )
    assert out_tok.shape == (B, 4) and n_acc.shape == (B,) and corr.shape == (B,)
    assert bool((corr >= 0).all())
