"""Ragged node-major tree dispatch: the exactness + padding-waste contracts.

``ragged="always"`` forces every tree step through the flat node-major
layout; ``ragged=False`` pins the padded (slots, Tpad) layout.  For
identical prompts/seeds the two must emit token-identical output — across
registry verifiers, sync and pipelined stepping, sharded and unsharded
pools, XLA and Pallas attention, heterogeneous selector actions — and the
``pad_nodes_total`` / ``tree_lanes_total`` counters must show the flat
layout shipping fewer lanes on heterogeneous mixes (docs/serving.md
"Ragged node-major tree batching").

Cross-engine selectors here key on stream CONTENT (the first committed
token), never on ``stream["rid"]``: rids are shard-local, so an rid-keyed
selector legitimately diverges between sharded and unsharded engines.
"""
import jax
import pytest

from repro.models.config import ModelConfig
from repro.models.transformer import init_params
from repro.serving.batch_engine import (
    BatchedSpeculativeEngine,
    ShardedBatchedSpeculativeEngine,
)
from repro.serving.engine import EngineConfig, SpeculativeEngine

V = 32

DENSE_T = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")
DENSE_D = ModelConfig(name="d", arch_type="dense", n_layers=1, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")
MOE_T = ModelConfig(name="m", arch_type="moe", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, d_ff=96, vocab=V, n_experts=4, top_k=2,
                    dtype="float32")

# prompt[0] is the selector's content key: stream 0 runs an aggressive
# action, everyone else a thin one — the adversarial padded-layout mix
PROMPTS = [[1, 2, 3], [0, 5], [0, 7, 8, 9], [0, 1]]
SEEDS = [20, 21, 22, 23]


def hetero_selector(stream, engine):
    return (2, 2, 2) if stream["committed"][0] == 1 else (1, 1, 0)


@pytest.fixture(scope="module")
def dense_models():
    return (DENSE_T, init_params(DENSE_T, jax.random.PRNGKey(0)),
            DENSE_D, init_params(DENSE_D, jax.random.PRNGKey(1)))


def _run(eng, prompts=PROMPTS, seeds=SEEDS, max_new=10):
    rids = [eng.submit(list(p), max_new=max_new, seed=sd)
            for p, sd in zip(prompts, seeds)]
    outs = eng.run()
    return [outs[r]["tokens"] for r in rids]


def _pair(tc, tp, dc, dp, ecfg, **kw):
    """A padded engine and a forced-ragged engine over the same pool shape."""
    pad = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4,
                                   ragged=False, **kw)
    rag = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4,
                                   ragged="always", **kw)
    return pad, rag


@pytest.mark.parametrize("verifier", ["specinfer", "traversal", "univer", "greedy_mpbv"])
def test_ragged_matches_padded_across_verifiers(dense_models, verifier):
    """The core identity, on the adversarial heterogeneous-action mix."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier=verifier, K=2, L1=1, L2=1, max_cache=128)
    pad, rag = _pair(tc, tp, dc, dp, ecfg, selector=hetero_selector)
    assert _run(rag) == _run(pad)
    # the flat buffer shipped strictly fewer lanes than the padded block
    assert rag.counters["tree_lanes_total"] < pad.counters["tree_lanes_total"]


def test_ragged_matches_independent_single_engines(dense_models):
    """Anchor: ragged == padded == N independent single-stream engines,
    so the identity chain bottoms out at the reference serving path."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    singles = []
    for p, sd in zip(PROMPTS, SEEDS):
        eng = SpeculativeEngine(
            tc, tp, dc, dp,
            EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128,
                         seed=sd))
        singles.append(eng.generate(list(p), max_new=10))
    rag = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4, ragged="always")
    assert _run(rag) == singles


@pytest.mark.parametrize("pipeline", [False, True], ids=["sync", "pipelined"])
def test_ragged_matches_padded_sharded(dense_models, pipeline):
    """Sharded x {sync, pipelined}: every shard dispatches its own ragged
    buffer, and the whole ensemble still matches the unsharded padded run."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    pad = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4,
                                   selector=hetero_selector, ragged=False)
    want = _run(pad)
    rag = ShardedBatchedSpeculativeEngine(
        tc, tp, dc, dp, ecfg, n_slots=4, data_shards=2,
        selector=hetero_selector, ragged="always", pipeline=pipeline)
    assert _run(rag) == want


@pytest.mark.slow
def test_ragged_pipelined_unsharded(dense_models):
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="traversal", K=2, L1=1, L2=1, max_cache=128)
    pad, rag = _pair(tc, tp, dc, dp, ecfg, pipeline=True)
    assert _run(rag, max_new=12) == _run(pad, max_new=12)


@pytest.mark.slow
def test_ragged_matches_padded_moe(dense_models):
    """The ragged owner indirection threads through the MoE macro-body."""
    _, _, dc, dp = dense_models
    tp = init_params(MOE_T, jax.random.PRNGKey(2))
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    pad, rag = _pair(MOE_T, tp, dc, dp, ecfg, selector=hetero_selector)
    assert _run(rag) == _run(pad)


@pytest.mark.slow
def test_ragged_pallas_paged_end_to_end(dense_models):
    """attention_impl='pallas' + paged pool: the ragged block-table kernel
    (scalar-prefetched owner steering) carries the whole serving loop."""
    _, _, dc, dp = dense_models
    cfg = DENSE_T.replace(name="tp", n_heads=2, n_kv_heads=1, head_dim=128)
    tp = init_params(cfg, jax.random.PRNGKey(3))
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    pad, rag = _pair(cfg, tp, dc, dp, ecfg)
    assert rag._ragged_ok, "pallas + paged pool must keep the ragged path on"
    pcfg = cfg.replace(attention_impl="pallas")
    ppad = BatchedSpeculativeEngine(pcfg, init_params(cfg, jax.random.PRNGKey(3)),
                                    dc, dp, ecfg, n_slots=4, ragged=False)
    prag = BatchedSpeculativeEngine(pcfg, init_params(cfg, jax.random.PRNGKey(3)),
                                    dc, dp, ecfg, n_slots=4, ragged="always")
    want = _run(ppad, max_new=6)
    assert _run(prag, max_new=6) == want
    # and the XLA engines agree with the pallas ones (impl-independence)
    assert _run(pad, max_new=6) == want


def test_ragged_pallas_ring_falls_back_padded(dense_models):
    """pallas + a non-paged ring pool has no block table to steer the ragged
    kernel: the engine must silently pin the padded layout, not crash."""
    _, _, dc, dp = dense_models
    cfg = DENSE_T.replace(name="tr", n_heads=2, n_kv_heads=1, head_dim=128,
                          attention_impl="pallas")
    tp = init_params(cfg, jax.random.PRNGKey(3))
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    rag = BatchedSpeculativeEngine(cfg, tp, dc, dp, ecfg, n_slots=4,
                                   paged=False, ragged="always")
    assert not rag._ragged_ok
    pad = BatchedSpeculativeEngine(cfg, tp, dc, dp, ecfg, n_slots=4,
                                   paged=False, ragged=False)
    assert _run(rag, max_new=6) == _run(pad, max_new=6)


def test_auto_ragged_heuristic_and_pad_counters(dense_models):
    """ragged=True (auto) goes ragged exactly when the flat buffer beats the
    padded lane count: heterogeneous mixes and drain tails qualify, and the
    pad counters record the win; outputs still match the padded engine."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    pad = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4,
                                   selector=hetero_selector, ragged=False)
    auto = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4,
                                    selector=hetero_selector, ragged=True)
    assert _run(auto) == _run(pad)
    cp, ca = pad.counters, auto.counters
    assert cp["tree_lanes_total"] > 0 and ca["tree_lanes_total"] > 0
    frac_pad = cp["pad_nodes_total"] / cp["tree_lanes_total"]
    frac_auto = ca["pad_nodes_total"] / ca["tree_lanes_total"]
    assert ca["tree_lanes_total"] < cp["tree_lanes_total"]
    assert frac_auto < frac_pad
    # both counters saw the same real work
    assert ca["target_tokens"] == cp["target_tokens"]
