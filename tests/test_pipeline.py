"""Pipelined stepping: the two-phase engine's exactness and overlap contract.

``BatchedSpeculativeEngine(pipeline=True)`` splits every iteration into
``begin_step`` (scheduling boundary + dispatch) and ``finish_step`` (verify,
fused commit, retire) and lets ``finish_step`` begin the next iteration
before its own retirement tail.  These tests pin the contract from
docs/serving.md "Pipelined stepping":

  * token identity with the synchronous engine for both target-pass
    strategies x both verifiers — including under admission stalls, paged
    block-pressure reclaim, and LIFO/capacity evictions landing at the
    begin_step boundary while a finished step's retirement is deferred;
  * the overlap really happens: the draft for step i+1 is dispatched before
    step i's verify phase (finish_step) completes (call-order probe, same
    style as test_commit_fused.py's one-commit-per-step assertion);
  * stall-and-drain: iterations that retire a stream never pipeline ahead,
    and a begun step can be aborted (rng + draft pool + speculative target
    writes rewound) without perturbing the token stream.
"""
import jax
import pytest

from repro.models.config import ModelConfig
from repro.models.transformer import init_params
from repro.serving.batch_engine import BatchedSpeculativeEngine
from repro.serving.engine import EngineConfig, SpeculativeEngine
from repro.serving.serve_step import StagingBuffers

V = 32

DENSE_T = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")
DENSE_D = ModelConfig(name="d", arch_type="dense", n_layers=1, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=96, vocab=V, dtype="float32")
SSM_CFG = ModelConfig(name="s", arch_type="ssm", n_layers=2, d_model=48, vocab=V,
                      ssm_state=16, ssm_headdim=16, ssm_chunk=8, dtype="float32")

PROMPTS = [[1, 2, 3], [4, 5], [6, 7, 8, 9]]
SEEDS = [20, 21, 22]


@pytest.fixture(scope="module")
def dense_models():
    return (DENSE_T, init_params(DENSE_T, jax.random.PRNGKey(0)),
            DENSE_D, init_params(DENSE_D, jax.random.PRNGKey(1)))


def _singles(tc, tp, dc, dp, ecfg, prompts, seeds, max_new):
    outs = []
    for p, sd in zip(prompts, seeds):
        eng = SpeculativeEngine(
            tc, tp, dc, dp,
            EngineConfig(verifier=ecfg.verifier, K=ecfg.K, L1=ecfg.L1, L2=ecfg.L2,
                         max_cache=ecfg.max_cache, seed=sd))
        outs.append(eng.generate(list(p), max_new=max_new))
    return outs


# ------------------------------------------------------- token identity ---


@pytest.mark.parametrize("verifier", ["specinfer", "traversal"])
def test_pipeline_matches_sync_tree_strategy(dense_models, verifier):
    """Tree strategy: pipelined == synchronous == per-stream singles, and the
    pipeline actually ran ahead at least once."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier=verifier, K=2, L1=1, L2=1, max_cache=128)
    singles = _singles(tc, tp, dc, dp, ecfg, PROMPTS, SEEDS, max_new=16)
    sync = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4)
    assert sync.generate_batch(PROMPTS, max_new=16, seeds=SEEDS) == singles
    pipe = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4, pipeline=True)
    assert pipe.strategy == "tree"
    assert pipe.generate_batch(PROMPTS, max_new=16, seeds=SEEDS) == singles
    assert pipe.counters["pipeline_ahead"] > 0
    # drained: nothing left in flight, pool fully released
    assert pipe._pending_next is None
    assert pipe.tpool.free_slots == 4 and pipe.dpool.free_slots == 4
    assert not pipe.dpool.frame_held


@pytest.mark.slow
@pytest.mark.parametrize("verifier", ["specinfer", "traversal"])
def test_pipeline_matches_sync_replay_strategy(verifier):
    """Replay strategy (recurrent target): the host-interleaved target pass
    rides the same begin/finish split, token-identically."""
    params = init_params(SSM_CFG, jax.random.PRNGKey(0))
    ecfg = EngineConfig(verifier=verifier, K=2, L1=1, L2=1, max_cache=128)
    sync = BatchedSpeculativeEngine(SSM_CFG, params, SSM_CFG, params, ecfg, n_slots=2)
    assert sync.strategy == "replay"
    want = sync.generate_batch(PROMPTS[:2], max_new=10, seeds=SEEDS[:2])
    pipe = BatchedSpeculativeEngine(SSM_CFG, params, SSM_CFG, params, ecfg,
                                    n_slots=2, pipeline=True)
    assert pipe.generate_batch(PROMPTS[:2], max_new=10, seeds=SEEDS[:2]) == want
    assert pipe.counters["pipeline_ahead"] > 0


def test_pipeline_admission_stalls_exact(dense_models):
    """More requests than slots: every finished stream stalls the pipeline
    (slot release feeds the next admission), queued requests are admitted at
    the boundary, and outputs still match the synchronous engine."""
    tc, tp, dc, dp = dense_models
    prompts = [[i + 1, i + 2] for i in range(5)]
    max_news = [6, 14, 10, 8, 12]
    seeds = [30 + i for i in range(5)]
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)

    def run(pipeline):
        eng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=2,
                                       pipeline=pipeline)
        rids = [eng.submit(p, max_new=mn, seed=sd)
                for p, sd, mn in zip(prompts, seeds, max_news)]
        outs = eng.run()
        return [outs[r]["tokens"] for r in rids], eng

    want, _ = run(False)
    got, pipe = run(True)
    assert got == want
    assert pipe.counters["pipeline_stalls"] > 0, "finishing streams must stall"
    assert pipe.counters["pipeline_ahead"] > 0, "steady state must overlap"
    assert pipe.tpool.free_slots == 2 and not pipe.streams and not pipe.queue


def test_pipeline_paged_pressure_reclaim_exact(dense_models):
    """Paged arena under pressure mid-pipeline: dead-tail reclamation (a
    selector shrinks its speculation bucket; a queued long prompt's
    admission recycles the dead tails) happens at the begin_step boundary
    and the token stream matches the synchronous paged engine."""
    tc, tp, dc, dp = dense_models

    def selector(stream, engine):
        return (2, 2, 2) if len(stream["committed"]) <= 4 else (1, 1, 1)

    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=64)
    prompts = [[1, 2, 3], [7, 6, 5], list(range(1, 18))]
    seeds, max_news = [40, 41, 42], [8, 8, 4]

    def run(pipeline):
        eng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, selector=selector,
                                       n_slots=3, paged=True, block_size=4,
                                       pool_blocks=7, pipeline=pipeline)
        rids = [eng.submit(p, max_new=m, seed=s)
                for p, s, m in zip(prompts, seeds, max_news)]
        outs = eng.run()
        return [(outs[r]["tokens"], outs[r]["reason"]) for r in rids], eng

    want, sync = run(False)
    got, pipe = run(True)
    assert got == want
    assert pipe.counters["blocks_reclaimed"] > 0
    assert pipe.counters["blocks_reclaimed"] == sync.counters["blocks_reclaimed"]
    assert pipe.counters["evicted"] == 0


def test_pipeline_evictions_exact(dense_models):
    """LIFO block-pressure eviction and ring-capacity eviction land at the
    begin_step boundary of a running pipeline; victims, reasons and every
    survivor's tokens match the synchronous engine."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=64)

    def run_paged(pipeline):
        eng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=2,
                                       paged=True, block_size=4, pool_blocks=8,
                                       pipeline=pipeline)
        r0 = eng.submit([1, 2, 3], max_new=24, seed=50)
        r1 = eng.submit([4, 5, 6], max_new=24, seed=51)
        outs = eng.run()
        return [(outs[r]["tokens"], outs[r]["reason"]) for r in (r0, r1)]

    got, want = run_paged(True), run_paged(False)
    assert got == want
    assert got[0][1] == "length" and got[1][1] == "evicted:pool_blocks"

    ecfg_small = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=24)

    def run_ring(pipeline):
        eng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg_small, n_slots=2,
                                       pipeline=pipeline)
        rid = eng.submit([1, 2, 3], max_new=64, seed=7)
        out = eng.run()[rid]
        return out["tokens"], out["reason"]

    got_ring, want_ring = run_ring(True), run_ring(False)
    assert got_ring == want_ring
    assert got_ring[1] == "evicted:cache_full"


# ------------------------------------------------------ overlap probing ---


class _ProbedEngine(BatchedSpeculativeEngine):
    """Records the interleaving of draft dispatches and finish completions —
    the call-order probe for the pipeline-ahead guarantee."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.calls = []

    def _ingest_deltas(self, active):
        self.calls.append("draft_dispatch")
        return super()._ingest_deltas(active)

    def finish_step(self, pending, pipeline_ahead=None):
        events = super().finish_step(pending, pipeline_ahead)
        self.calls.append("finish_done")
        return events


def test_draft_dispatched_before_verify_completes(dense_models):
    """Acceptance probe: in pipelined mode the draft ingest for step i+1 is
    dispatched inside step i's finish_step — i.e. BEFORE the verify phase
    completes — while the synchronous engine strictly alternates."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)

    def trace(pipeline):
        eng = _ProbedEngine(tc, tp, dc, dp, ecfg, n_slots=4, pipeline=pipeline)
        outs = eng.generate_batch(PROMPTS, max_new=12, seeds=SEEDS)
        return eng, outs

    sync, outs_s = trace(False)
    # synchronous: every draft dispatch strictly follows the previous finish
    assert sync.calls == ["draft_dispatch", "finish_done"] * (len(sync.calls) // 2)

    pipe, outs_p = trace(True)
    assert outs_p == outs_s
    # pipelined: at least one step's draft is dispatched before the previous
    # finish completes — consecutive draft dispatches with no finish between
    ahead = any(a == b == "draft_dispatch"
                for a, b in zip(pipe.calls, pipe.calls[1:]))
    assert ahead, f"no overlapped dispatch in call trace {pipe.calls}"
    assert pipe.counters["pipeline_ahead"] > 0


def test_stalled_iterations_do_not_run_ahead(dense_models):
    """Every iteration that finishes a stream must stall: pipeline_ahead +
    pipeline_stalls partitions the finished iterations, and with a single
    stream of homogeneous length the final iteration always stalls."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    eng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=1, pipeline=True)
    eng.submit([1, 2, 3], max_new=12, seed=20)
    eng.run()
    c = eng.counters
    assert c["pipeline_stalls"] >= 1  # the finishing iteration stalled
    assert c["pipeline_ahead"] + c["pipeline_stalls"] > 0
    assert eng._pending_next is None


# ----------------------------------------------------- drain and abort ---


def test_abort_step_rewinds_exactly(dense_models):
    """A begun step can be abandoned: rng snapshots restore the consumed
    draws, the draft pool rolls back to its double-buffered frame, and the
    target rows' speculative writes are invalidated — a subsequent run
    emits exactly the untouched token stream."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    want = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4) \
        .generate_batch(PROMPTS, max_new=12, seeds=SEEDS)
    eng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4, pipeline=True)
    for p, sd in zip(PROMPTS, SEEDS):
        eng.submit(p, max_new=12, seed=sd)
    pending = eng.begin_step()  # dispatches ingest + draft + tree pass
    eng.abort_step(pending)     # ...and rewinds all of it
    assert not eng.dpool.frame_held
    rids = sorted(st["rid"] for st in eng.streams.values())
    outs = eng.run()
    assert [outs[r]["tokens"] for r in rids] == want


def test_drain_pipeline_finishes_pending(dense_models):
    """drain_pipeline retires the begun-ahead step without beginning another
    — the engine is then quiescent (safe for out-of-band mutations) and the
    remaining run still matches."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)
    want = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4) \
        .generate_batch(PROMPTS, max_new=12, seeds=SEEDS)
    eng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=4, pipeline=True)
    rids = [eng.submit(p, max_new=12, seed=sd) for p, sd in zip(PROMPTS, SEEDS)]
    eng.step()  # leaves the next step begun-ahead (steady state)
    assert eng._pending_next is not None
    eng.drain_pipeline()
    assert eng._pending_next is None
    assert eng.drain_pipeline() == []  # idempotent no-op when quiescent
    outs = eng.run()
    for r in rids:
        assert outs[r]["tokens"] == want[rids.index(r)]


def test_submit_mid_pipeline_admits_like_sync(dense_models):
    """A submit() landing while a step is begun-ahead must not slip its
    admission by one iteration: the pending step is aborted (rng + pools
    rewound) so the request joins at exactly the boundary the synchronous
    engine would, and every stream's tokens match the same call trace with
    pipeline=False."""
    tc, tp, dc, dp = dense_models
    ecfg = EngineConfig(verifier="specinfer", K=2, L1=1, L2=1, max_cache=128)

    def run(pipeline):
        eng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=2,
                                       pipeline=pipeline)
        r0 = eng.submit([1, 2, 3], max_new=12, seed=20)
        eng.step()
        eng.step()  # pipelined: leaves step 3 begun-ahead without r1
        r1 = eng.submit([4, 5], max_new=8, seed=21)
        outs = eng.run()
        return [outs[r]["tokens"] for r in (r0, r1)]

    got = run(True)
    assert got == run(False)

    # with zero free rows admission is provably unchanged: the begun-ahead
    # step is kept (no aborted device work), and the queued request still
    # matches its synchronous run
    def run_full(pipeline):
        eng = BatchedSpeculativeEngine(tc, tp, dc, dp, ecfg, n_slots=1,
                                       pipeline=pipeline)
        r0 = eng.submit([1, 2, 3], max_new=12, seed=20)
        eng.step()
        pending = eng._pending_next
        r1 = eng.submit([4, 5], max_new=8, seed=21)
        if pipeline:
            assert eng._pending_next is pending, \
                "no free row: the dispatched step must be kept"
        outs = eng.run()
        return [outs[r]["tokens"] for r in (r0, r1)]

    assert run_full(True) == run_full(False)


def test_staging_banks_isolated():
    """StagingBuffers: a flipped bank never hands back the buffer the
    previous bank's arrays were staged in (the pipelined no-overwrite
    contract); a single bank reuses storage."""
    import numpy as np

    two = StagingBuffers(banks=2)
    a = two.get("toks", (4,), np.int32)
    a[:] = 7
    two.flip()
    b = two.get("toks", (4,), np.int32)
    assert b is not a and a[0] == 7  # bank 0's staging untouched
    two.flip()
    assert two.get("toks", (4,), np.int32) is a  # round-robin reuse

    one = StagingBuffers(banks=1)
    x = one.get("toks", (4,), np.int32)
    one.flip()
    assert one.get("toks", (4,), np.int32) is x
